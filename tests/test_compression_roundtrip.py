"""Codec round-trips on awkward shapes + wire-bytes accounting
(core/compression.py).

The Fig. 7 bandwidth reproduction is only as honest as the codecs' byte
accounting: the reported wire bytes must be derivable from the *decoded*
payload (logical elements), not from kernel-side padded tile layouts.
These tests sweep non-2D and odd-sized shapes through ``quant8`` and
``sparse`` and check both fidelity and the accounting identity.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import StreamBuffer, compression as comp

ODD_SHAPES = [(1,), (7,), (129,), (3, 5), (13, 7), (3, 5, 2), (2, 3, 4, 5),
              ()]


def _buf(shape, fill="ramp") -> StreamBuffer:
    n = int(np.prod(shape)) if shape else 1
    x = (np.arange(n, dtype=np.float32).reshape(shape) - n / 2) / max(n, 1)
    return StreamBuffer(tensors=(jnp.asarray(x),), pts=jnp.int32(3))


class TestQuant8:
    @pytest.mark.parametrize("shape", ODD_SHAPES)
    def test_roundtrip_any_rank(self, shape):
        buf = _buf(shape)
        enc, nbytes = comp.encode(buf, "quant8")
        dec = comp.decode(enc, "quant8")
        out = dec.tensors[0]
        assert out.shape == tuple(shape)
        assert out.dtype == buf.tensors[0].dtype
        # 8-bit quantization: error bounded by one step of the block scale
        scale = float(np.max(np.abs(np.asarray(buf.tensors[0])))) / 127 + 1e-8
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(buf.tensors[0]), atol=scale)

    @pytest.mark.parametrize("shape", ODD_SHAPES)
    def test_wire_bytes_match_decoded_payload(self, shape):
        buf = _buf(shape)
        enc, nbytes = comp.encode(buf, "quant8")
        dec = comp.decode(enc, "quant8")
        # accounting identity: 1 byte per DECODED element + 4 per scale —
        # padded kernel tiles must never leak into the wire bytes
        logical = sum(int(np.asarray(t).size) for t in dec.tensors)
        scales = sum(int(e["scale"].size) for e in enc.tensors)
        assert nbytes == logical + 4 * scales
        # and the padded q tile really is bigger (or equal) on odd shapes
        padded = sum(int(e["q"].size) for e in enc.tensors)
        assert padded >= logical

    def test_multi_tensor_buffer(self):
        buf = StreamBuffer(tensors=(jnp.ones((3, 5)), jnp.zeros((7,))),
                           pts=jnp.int32(0))
        enc, nbytes = comp.encode(buf, "quant8")
        dec = comp.decode(enc, "quant8")
        assert len(dec.tensors) == 2
        assert dec.tensors[0].shape == (3, 5) and dec.tensors[1].shape == (7,)


class TestSparse:
    @pytest.mark.parametrize("shape", [(7,), (129,), (3, 5), (13, 7),
                                       (3, 5, 2)])
    def test_roundtrip_any_rank(self, shape):
        # 10% density payload under the default 25% capacity: lossless
        n = int(np.prod(shape))
        x = np.zeros(n, np.float32)
        nz = np.arange(0, n, 10)
        x[nz] = np.arange(1, len(nz) + 1, dtype=np.float32)
        buf = StreamBuffer(tensors=(jnp.asarray(x.reshape(shape)),),
                           pts=jnp.int32(0))
        enc, nbytes = comp.encode(buf, "sparse")
        dec = comp.decode(enc, "sparse")
        assert dec.tensors[0].shape == tuple(shape)
        np.testing.assert_array_equal(np.asarray(dec.tensors[0]),
                                      x.reshape(shape))

    @pytest.mark.parametrize("shape", [(7,), (13, 7), (3, 5, 2)])
    def test_wire_bytes_match_coo_framing(self, shape):
        buf = _buf(shape)
        enc, nbytes = comp.encode(buf, "sparse")
        total = 0
        for sp in enc.tensors:
            # capacity-bounded COO framing: values + int32 indices + count
            total += int(sp.values.size) * sp.values.dtype.itemsize \
                + int(sp.indices.size) * 4 + 4
        assert nbytes == total
        dec = comp.decode(enc, "sparse")
        assert dec.tensors[0].shape == tuple(shape)

    def test_density_parameter_bounds_capacity(self):
        buf = _buf((40,))
        _, wide = comp.encode(buf, "sparse:0.5")
        _, narrow = comp.encode(buf, "sparse:0.1")
        assert narrow < wide

    def test_roundtrip_via_query_meta_codec(self):
        """The query path stores the codec in buffer meta; decode must key
        off it identically (the batcher's gather path relies on this)."""
        buf = _buf((13, 7))
        enc, _ = comp.encode(buf, "quant8")
        assert enc.meta["codec"] == "quant8"
        dec = comp.decode(enc, enc.meta["codec"])
        assert dec.tensors[0].shape == (13, 7)


class TestDecodeStripsWireMeta:
    """Regression: decode() used to leave the wire buffer's meta["codec"]
    ("quant8"/"sparse") on the DECODED frame — a decoded frame claiming to
    be encoded, so a meta-keyed decode(buf, buf.meta["codec"]) would decode
    a second time (corrupting the payload) and wire accounting would count
    dense frames as compressed."""

    @pytest.mark.parametrize("codec", ["quant8", "sparse"])
    def test_decoded_frame_never_claims_a_codec(self, codec):
        buf = _buf((13, 7))
        enc, _ = comp.encode(buf, codec)
        assert enc.meta["codec"] == codec          # the WIRE form does claim
        dec = comp.decode(enc, codec)
        assert "codec" not in dec.meta             # the decoded frame doesn't
        assert "sparse_dropped" not in dec.meta

    @pytest.mark.parametrize("codec", ["quant8", "sparse"])
    def test_meta_keyed_double_decode_is_identity(self, codec):
        """The hazard pattern itself: decode keyed off the buffer's own meta
        must be a no-op once the buffer is already decoded."""
        buf = _buf((13, 7))
        enc, _ = comp.encode(buf, codec)
        dec = comp.decode(enc, enc.meta.get("codec", "none"))
        dec2 = comp.decode(dec, dec.meta.get("codec", "none"))
        np.testing.assert_array_equal(np.asarray(dec2.tensors[0]),
                                      np.asarray(dec.tensors[0]))

    def test_payload_meta_survives_decode(self):
        """Only the wire-form keys are stripped; routing/payload meta rides
        through untouched (the batcher hoists routing separately)."""
        buf = _buf((3, 5)).with_(meta={"client_id": 7, "topic": "cam/a"})
        enc, _ = comp.encode(buf, "quant8")
        dec = comp.decode(enc, "quant8")
        assert dec.meta == {"client_id": 7, "topic": "cam/a"}


class TestSparseTruncationAccounting:
    """Regression: a dense tensor forced through a narrow sparse capacity
    used to truncate SILENTLY — lossy wire frames with no signal anywhere.
    The encode must detect the loss, stamp it on the wire buffer, and
    aggregate it in the codec stats."""

    def test_dense_tensor_at_density_0p05_reports_truncation(self):
        comp.reset_codec_stats()
        n = 200
        x = jnp.asarray(np.arange(1, n + 1, dtype=np.float32))  # fully dense
        buf = StreamBuffer(tensors=(x,), pts=jnp.int32(0))
        enc, _ = comp.encode(buf, "sparse:0.05")
        kept = int(np.asarray(
            comp.decode(enc, "sparse").tensors[0] != 0).sum())
        dropped = enc.meta["sparse_dropped"]
        assert dropped > 0
        assert kept + dropped == n                 # loss fully accounted
        stats = comp.codec_stats()
        assert stats["sparse_truncated_tensors"] == 1
        assert stats["sparse_dropped_values"] == dropped

    def test_lossless_encode_stays_unmarked(self):
        """A payload under capacity must NOT grow the truncation meta key —
        the lossless case keeps its treedef (and its silence)."""
        comp.reset_codec_stats()
        x = np.zeros(200, np.float32)
        x[::25] = 1.0                               # 4% nonzero, 25% capacity
        buf = StreamBuffer(tensors=(jnp.asarray(x),), pts=jnp.int32(0))
        enc, _ = comp.encode(buf, "sparse")
        assert "sparse_dropped" not in enc.meta
        assert comp.codec_stats()["sparse_dropped_values"] == 0
        dec = comp.decode(enc, "sparse")
        np.testing.assert_array_equal(np.asarray(dec.tensors[0]), x)

    def test_multi_tensor_truncation_sums_across_tensors(self):
        comp.reset_codec_stats()
        dense = jnp.asarray(np.arange(1, 101, dtype=np.float32))
        buf = StreamBuffer(tensors=(dense, dense), pts=jnp.int32(0))
        enc, _ = comp.encode(buf, "sparse:0.05")
        assert comp.codec_stats()["sparse_truncated_tensors"] == 2
        assert enc.meta["sparse_dropped"] == \
            comp.codec_stats()["sparse_dropped_values"]


class TestDensityCapAlignment:
    """Regression (PR-5): ``cap = int(size * density)`` spread the capacity
    evenly over ceil(size/512) blocks, so a tensor whose size is not a
    multiple of the sparse block got fewer per-block slots than its largest
    block could need — ``sparse:1.0`` (nominally lossless) silently dropped
    values when the nonzeros concentrated in one block.  Full density now
    pins every block at full capacity."""

    @pytest.mark.parametrize("n", [600, 513, 1023, 200])
    def test_full_density_is_lossless_any_size(self, n):
        comp.reset_codec_stats()
        x = jnp.asarray(np.arange(1, n + 1, dtype=np.float32))  # fully dense
        buf = StreamBuffer(tensors=(x,), pts=jnp.int32(0))
        enc, _ = comp.encode(buf, "sparse:1.0")
        assert "sparse_dropped" not in enc.meta
        assert comp.codec_stats()["sparse_dropped_values"] == 0
        dec = comp.decode(enc, "sparse")
        np.testing.assert_array_equal(np.asarray(dec.tensors[0]),
                                      np.asarray(x))

    def test_over_unity_density_clamps_to_lossless(self):
        x = jnp.asarray(np.arange(1, 601, dtype=np.float32))
        buf = StreamBuffer(tensors=(x,), pts=jnp.int32(0))
        enc, _ = comp.encode(buf, "sparse:1.5")
        assert "sparse_dropped" not in enc.meta
        dec = comp.decode(enc, "sparse")
        np.testing.assert_array_equal(np.asarray(dec.tensors[0]),
                                      np.asarray(x))

    def test_non_multiple_size_partial_density_roundtrips(self):
        """Sizes off the 512 block grid still round-trip exactly when the
        payload fits the requested capacity."""
        n = 700                       # 2 blocks, second only 188 wide
        x = np.zeros(n, np.float32)
        x[::10] = np.arange(1, 71, dtype=np.float32)   # 10% nonzero
        buf = StreamBuffer(tensors=(jnp.asarray(x),), pts=jnp.int32(0))
        enc, _ = comp.encode(buf, "sparse:0.5")
        assert "sparse_dropped" not in enc.meta
        dec = comp.decode(enc, "sparse")
        np.testing.assert_array_equal(np.asarray(dec.tensors[0]), x)

    def test_partial_density_truncation_still_accounted(self):
        """The cap fix must not weaken the loss signal below unity."""
        comp.reset_codec_stats()
        x = jnp.asarray(np.arange(1, 601, dtype=np.float32))
        buf = StreamBuffer(tensors=(x,), pts=jnp.int32(0))
        enc, _ = comp.encode(buf, "sparse:0.05")
        kept = int(np.asarray(
            comp.decode(enc, "sparse").tensors[0] != 0).sum())
        assert enc.meta["sparse_dropped"] == 600 - kept > 0


def test_unknown_codec_rejected():
    with pytest.raises(ValueError, match="unknown codec"):
        comp.encode(_buf((3,)), "gzip")
    with pytest.raises(ValueError, match="unknown codec"):
        comp.decode(_buf((3,)), "gzip")
