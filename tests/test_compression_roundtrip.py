"""Codec round-trips on awkward shapes + wire-bytes accounting
(core/compression.py).

The Fig. 7 bandwidth reproduction is only as honest as the codecs' byte
accounting: the reported wire bytes must be derivable from the *decoded*
payload (logical elements), not from kernel-side padded tile layouts.
These tests sweep non-2D and odd-sized shapes through ``quant8`` and
``sparse`` and check both fidelity and the accounting identity.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import StreamBuffer, compression as comp

ODD_SHAPES = [(1,), (7,), (129,), (3, 5), (13, 7), (3, 5, 2), (2, 3, 4, 5),
              ()]


def _buf(shape, fill="ramp") -> StreamBuffer:
    n = int(np.prod(shape)) if shape else 1
    x = (np.arange(n, dtype=np.float32).reshape(shape) - n / 2) / max(n, 1)
    return StreamBuffer(tensors=(jnp.asarray(x),), pts=jnp.int32(3))


class TestQuant8:
    @pytest.mark.parametrize("shape", ODD_SHAPES)
    def test_roundtrip_any_rank(self, shape):
        buf = _buf(shape)
        enc, nbytes = comp.encode(buf, "quant8")
        dec = comp.decode(enc, "quant8")
        out = dec.tensors[0]
        assert out.shape == tuple(shape)
        assert out.dtype == buf.tensors[0].dtype
        # 8-bit quantization: error bounded by one step of the block scale
        scale = float(np.max(np.abs(np.asarray(buf.tensors[0])))) / 127 + 1e-8
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(buf.tensors[0]), atol=scale)

    @pytest.mark.parametrize("shape", ODD_SHAPES)
    def test_wire_bytes_match_decoded_payload(self, shape):
        buf = _buf(shape)
        enc, nbytes = comp.encode(buf, "quant8")
        dec = comp.decode(enc, "quant8")
        # accounting identity: 1 byte per DECODED element + 4 per scale —
        # padded kernel tiles must never leak into the wire bytes
        logical = sum(int(np.asarray(t).size) for t in dec.tensors)
        scales = sum(int(e["scale"].size) for e in enc.tensors)
        assert nbytes == logical + 4 * scales
        # and the padded q tile really is bigger (or equal) on odd shapes
        padded = sum(int(e["q"].size) for e in enc.tensors)
        assert padded >= logical

    def test_multi_tensor_buffer(self):
        buf = StreamBuffer(tensors=(jnp.ones((3, 5)), jnp.zeros((7,))),
                           pts=jnp.int32(0))
        enc, nbytes = comp.encode(buf, "quant8")
        dec = comp.decode(enc, "quant8")
        assert len(dec.tensors) == 2
        assert dec.tensors[0].shape == (3, 5) and dec.tensors[1].shape == (7,)


class TestSparse:
    @pytest.mark.parametrize("shape", [(7,), (129,), (3, 5), (13, 7),
                                       (3, 5, 2)])
    def test_roundtrip_any_rank(self, shape):
        # 10% density payload under the default 25% capacity: lossless
        n = int(np.prod(shape))
        x = np.zeros(n, np.float32)
        nz = np.arange(0, n, 10)
        x[nz] = np.arange(1, len(nz) + 1, dtype=np.float32)
        buf = StreamBuffer(tensors=(jnp.asarray(x.reshape(shape)),),
                           pts=jnp.int32(0))
        enc, nbytes = comp.encode(buf, "sparse")
        dec = comp.decode(enc, "sparse")
        assert dec.tensors[0].shape == tuple(shape)
        np.testing.assert_array_equal(np.asarray(dec.tensors[0]),
                                      x.reshape(shape))

    @pytest.mark.parametrize("shape", [(7,), (13, 7), (3, 5, 2)])
    def test_wire_bytes_match_coo_framing(self, shape):
        buf = _buf(shape)
        enc, nbytes = comp.encode(buf, "sparse")
        total = 0
        for sp in enc.tensors:
            # capacity-bounded COO framing: values + int32 indices + count
            total += int(sp.values.size) * sp.values.dtype.itemsize \
                + int(sp.indices.size) * 4 + 4
        assert nbytes == total
        dec = comp.decode(enc, "sparse")
        assert dec.tensors[0].shape == tuple(shape)

    def test_density_parameter_bounds_capacity(self):
        buf = _buf((40,))
        _, wide = comp.encode(buf, "sparse:0.5")
        _, narrow = comp.encode(buf, "sparse:0.1")
        assert narrow < wide

    def test_roundtrip_via_query_meta_codec(self):
        """The query path stores the codec in buffer meta; decode must key
        off it identically (the batcher's gather path relies on this)."""
        buf = _buf((13, 7))
        enc, _ = comp.encode(buf, "quant8")
        assert enc.meta["codec"] == "quant8"
        dec = comp.decode(enc, enc.meta["codec"])
        assert dec.tensors[0].shape == (13, 7)


def test_unknown_codec_rejected():
    with pytest.raises(ValueError, match="unknown codec"):
        comp.encode(_buf((3,)), "gzip")
    with pytest.raises(ValueError, match="unknown codec"):
        comp.decode(_buf((3,)), "gzip")
