"""Minimal, deterministic stand-in for the ``hypothesis`` property-testing
API, used only when the real package is not installed (tests/conftest.py adds
this directory to ``sys.path`` as a fallback).

Scope: exactly the subset the test-suite uses — ``@given`` over positional
strategies, ``@settings(max_examples=..., deadline=...)``, and the strategies
``integers``, ``floats``, ``sampled_from``, ``text``, ``lists``.

Semantics differ from real hypothesis in two deliberate ways:

* examples are DETERMINISTIC (seeded PRNG + boundary values first), so a
  failure reproduces identically on every run — no example database, no
  shrinking; the falsifying example is reported in the failure message;
* ``deadline`` and any other settings besides ``max_examples`` are ignored.
"""
from __future__ import annotations

import functools
import random

from . import strategies  # noqa: F401  (re-export: `from hypothesis import strategies as st`)

__all__ = ["given", "settings", "strategies"]

_SEED = 0x5EED_CAFE
_DEFAULT_MAX_EXAMPLES = 20


def settings(**kw):
    """Decorator recording settings; only ``max_examples`` is honoured."""

    def deco(fn):
        fn._hyp_settings = dict(kw)
        return fn

    return deco


def given(*strats):
    """Run the wrapped test over deterministic example draws.

    Boundary values (min/max/etc.) come first, then seeded random draws up
    to ``max_examples``.  Works above or below ``@settings`` and on both
    plain functions and methods (extra leading args pass through).
    """

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            conf = getattr(wrapper, "_hyp_settings", None) or \
                getattr(fn, "_hyp_settings", None) or {}
            max_examples = int(conf.get("max_examples", _DEFAULT_MAX_EXAMPLES))
            rnd = random.Random(_SEED)
            edge_lists = [s.edges() for s in strats]
            n_edge = min(max_examples,
                         max((len(e) for e in edge_lists), default=0))
            examples = [tuple(e[i % len(e)] for e in edge_lists)
                        for i in range(n_edge)]
            while len(examples) < max_examples:
                examples.append(tuple(s.example(rnd) for s in strats))
            for ex in examples:
                try:
                    fn(*args, *ex, **kwargs)
                except Exception as e:
                    argrepr = ", ".join(repr(v) for v in ex)
                    raise AssertionError(
                        f"Falsifying example: {fn.__name__}({argrepr})"
                    ) from e

        # pytest must see the wrapper's (*args) signature, not the wrapped
        # test's — otherwise strategy parameters look like missing fixtures
        del wrapper.__wrapped__
        # mirror real hypothesis's attribute shape: third-party pytest
        # plugins (e.g. anyio) probe `fn.hypothesis.inner_test`
        wrapper.hypothesis = type("_Hyp", (), {"inner_test": staticmethod(fn)})()
        return wrapper

    return deco
