"""Strategy objects for the vendored hypothesis shim (see __init__.py).

Each strategy exposes ``edges()`` — the deterministic boundary examples run
first — and ``example(rnd)`` — one seeded random draw.
"""
from __future__ import annotations

__all__ = ["integers", "floats", "sampled_from", "text", "lists"]


class _Strategy:
    def edges(self):
        return []

    def example(self, rnd):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, min_value, max_value):
        self.min_value = int(min_value)
        self.max_value = int(max_value)

    def edges(self):
        out = [self.min_value, self.max_value]
        for probe in (0, 1, -1):
            if self.min_value < probe < self.max_value:
                out.append(probe)
        return list(dict.fromkeys(out))

    def example(self, rnd):
        return rnd.randint(self.min_value, self.max_value)


class _Floats(_Strategy):
    def __init__(self, min_value, max_value):
        self.min_value = float(min_value)
        self.max_value = float(max_value)

    def edges(self):
        mid = 0.5 * (self.min_value + self.max_value)
        return list(dict.fromkeys([self.min_value, self.max_value, mid]))

    def example(self, rnd):
        return rnd.uniform(self.min_value, self.max_value)


class _SampledFrom(_Strategy):
    def __init__(self, elements):
        self.elements = list(elements)
        if not self.elements:
            raise ValueError("sampled_from needs a non-empty collection")

    def edges(self):
        return list(self.elements)

    def example(self, rnd):
        return rnd.choice(self.elements)


class _Text(_Strategy):
    def __init__(self, alphabet, min_size, max_size):
        self.alphabet = list(alphabet)
        self.min_size = int(min_size)
        self.max_size = int(max_size)
        if not self.alphabet and self.min_size > 0:
            raise ValueError("text() with empty alphabet and min_size > 0")

    def edges(self):
        out = []
        if self.alphabet:
            out.append(self.alphabet[0] * self.min_size)
            out.append(self.alphabet[-1] * self.max_size)
        elif self.min_size == 0:
            out.append("")
        return list(dict.fromkeys(out))

    def example(self, rnd):
        size = rnd.randint(self.min_size, self.max_size)
        return "".join(rnd.choice(self.alphabet) for _ in range(size))


class _Lists(_Strategy):
    def __init__(self, elements, min_size, max_size):
        self.elements = elements
        self.min_size = int(min_size)
        self.max_size = int(max_size)

    def edges(self):
        elem_edges = self.elements.edges() or [None]
        out = []
        if elem_edges[0] is not None:
            out.append([elem_edges[0]] * self.min_size)
            out.append([elem_edges[-1]] * self.max_size)
        elif self.min_size == 0:
            out.append([])
        return out

    def example(self, rnd):
        size = rnd.randint(self.min_size, self.max_size)
        return [self.elements.example(rnd) for _ in range(size)]


def integers(min_value, max_value):
    return _Integers(min_value, max_value)


def floats(min_value, max_value):
    return _Floats(min_value, max_value)


def sampled_from(elements):
    return _SampledFrom(elements)


def text(alphabet="abcdefghijklmnopqrstuvwxyz", min_size=0, max_size=10):
    return _Text(alphabet, min_size, max_size)


def lists(elements, min_size=0, max_size=10):
    return _Lists(elements, min_size, max_size)
