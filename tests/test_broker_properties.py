"""Property tests for broker discovery semantics (DESIGN.md §3).

``topic_matches`` and ``Broker.discover`` are the control-plane primitives
every binding decision rests on; these pin their algebra — wildcard
matching, ``require=`` spec filters, down-registration exclusion, ordering —
against brute-force oracles over generated topic/registration sets.  Runs
under real hypothesis when installed, else the deterministic vendored shim
(tests/_vendor).
"""
from hypothesis import given, settings, strategies as st

from repro.core import Broker, Caps, topic_matches

# Small alphabet so generated topics collide often — collisions are where
# wildcard/filter bugs live.
SEG = st.sampled_from(["a", "b", "cz", "09"])
SEGS = st.lists(SEG, min_size=1, max_size=4)


def brute_match(pattern: str, topic: str) -> bool:
    """Reference MQTT matcher, written the slow recursive way."""
    def rec(pp, tt):
        if not pp:
            return not tt
        if pp[0] == "#":
            return True
        if not tt:
            return False
        if pp[0] != "+" and pp[0] != tt[0]:
            return False
        return rec(pp[1:], tt[1:])
    return rec(pattern.strip("/").split("/"), topic.strip("/").split("/"))


class TestTopicMatchingProperties:
    @given(SEGS, SEGS)
    @settings(max_examples=80, deadline=None)
    def test_matches_brute_force_oracle(self, psegs, tsegs):
        pattern, topic = "/".join(psegs), "/".join(tsegs)
        assert topic_matches(pattern, topic) == brute_match(pattern, topic)

    @given(SEGS)
    @settings(max_examples=40, deadline=None)
    def test_self_match_and_universal_hash(self, segs):
        topic = "/".join(segs)
        assert topic_matches(topic, topic)
        assert topic_matches("#", topic)

    @given(SEGS, st.integers(min_value=0, max_value=3))
    @settings(max_examples=60, deadline=None)
    def test_plus_substitution_matches_any_single_level(self, segs, i):
        i = min(i, len(segs) - 1)
        pattern = "/".join("+" if j == i else s for j, s in enumerate(segs))
        assert topic_matches(pattern, "/".join(segs))
        # '+' never spans levels: extending the topic breaks the match
        assert not topic_matches(pattern, "/".join(segs + ["x"]))

    @given(SEGS, SEGS)
    @settings(max_examples=40, deadline=None)
    def test_hash_suffix_matches_all_extensions(self, base, ext):
        pattern = "/".join(base + ["#"])
        assert topic_matches(pattern, "/".join(base + ext))

    @given(SEGS)
    @settings(max_examples=40, deadline=None)
    def test_pattern_longer_than_topic_never_matches(self, segs):
        # (unless the extra level is '#', which matches the empty remainder
        # only at the position it appears)
        pattern = "/".join(segs + ["x"])
        assert not topic_matches(pattern, "/".join(segs))


def _fill(n_regs, version_of, down_mask):
    """Build a broker with n registrations on colliding topics; returns
    (broker, regs, expected-alive-list)."""
    b = Broker()
    topics = ["svc/a", "svc/b", "svc/a/b", "other/x"]
    regs = []
    for i in range(n_regs):
        reg = b.register(topics[i % len(topics)], Caps.ANY, f"ep{i}",
                         version=version_of(i))
        regs.append(reg)
    for i, reg in enumerate(regs):
        if down_mask(i):
            b.mark_down(reg)
    return b, regs


class TestDiscoverProperties:
    @given(st.integers(min_value=0, max_value=8),
           st.integers(min_value=1, max_value=3),
           st.integers(min_value=0, max_value=7))
    @settings(max_examples=60, deadline=None)
    def test_discover_equals_brute_force_filter(self, n, vmod, downbits):
        b, regs = _fill(n, lambda i: i % vmod, lambda i: bool(downbits >> (i % 3) & 1))
        for pattern in ("svc/#", "svc/+", "#", "svc/a", "nope/+"):
            got = b.discover(pattern)
            want = [r for r in regs
                    if r.alive and brute_match(pattern, r.topic)]
            assert got == sorted(want, key=lambda r: r.reg_id)

    @given(st.integers(min_value=1, max_value=8),
           st.integers(min_value=1, max_value=3))
    @settings(max_examples=40, deadline=None)
    def test_require_is_exact_spec_equality(self, n, vmod):
        b, regs = _fill(n, lambda i: i % vmod, lambda i: False)
        for v in range(vmod + 1):       # vmod: a version nobody declared
            got = b.discover("#", require={"version": v})
            assert got == [r for r in regs if r.specs["version"] == v]
        # a key nobody declares matches nothing (missing != None-equal)
        assert b.discover("#", require={"model": "x"}) == []

    @given(st.integers(min_value=1, max_value=8))
    @settings(max_examples=30, deadline=None)
    def test_down_registrations_are_excluded_until_revived(self, n):
        b, regs = _fill(n, lambda i: 0, lambda i: True)   # all down
        assert b.discover("#") == []
        for reg in regs:
            b.revive(reg)
        assert b.discover("#") == regs

    @given(st.integers(min_value=2, max_value=8))
    @settings(max_examples=30, deadline=None)
    def test_discover_order_is_registration_order(self, n):
        b, regs = _fill(n, lambda i: 0, lambda i: False)
        got = b.discover("#")
        assert [r.reg_id for r in got] == sorted(r.reg_id for r in got)
