"""Property tests for the QoS admission core (DESIGN.md §9).

``AdmissionQueue`` is the one scheduling function behind all four
batchers, so its algebra gets the property treatment the broker got:
generated tenant mixes and arrival interleavings against brute-force
oracles.  Pinned laws:

* pass-through mode (``qos=None``) IS the pre-QoS global FIFO — exact
  arrival order, nothing shed, nothing reordered (the bitwise-parity
  contract rests on this);
* weighted-fair scheduling preserves PER-TENANT FIFO: whatever the
  class interleaving, one tenant's requests serve in arrival order;
* no non-empty priority class starves — service share is bounded below
  by its weight fraction (stride-scheduling oracle);
* shedding is deterministic: the same scripted arrivals + tick script
  produce the identical per-tenant ledger, run after run;
* conservation: ``admitted == served + shed + queued + in_flight`` at
  every observable instant.

Runs under real hypothesis when installed, else the deterministic
vendored shim (tests/_vendor).
"""
import math

from hypothesis import given, settings, strategies as st

from chaoslib import burst_schedule, tenant_arrivals, zipf_tenants
from repro.core.admission import (AdmissionQueue, QoSConfig, TenantSpec,
                                  percentile_from_hist)

TENANTS = ["rt", "std", "batch"]
TENANT = st.sampled_from(TENANTS)
ARRIVALS = st.lists(TENANT, min_size=1, max_size=24)
TAKE_SIZES = st.lists(st.integers(min_value=1, max_value=4),
                      min_size=1, max_size=12)


class _Raw:
    """Stand-in wire buffer: the admission layer only reads ``.meta``."""

    def __init__(self, tenant=None, client=None, tag=None):
        self.meta = {}
        if tenant is not None:
            self.meta["tenant_id"] = tenant
        if client is not None:
            self.meta["client_id"] = client
        self.tag = tag


def _qos(serve_per_tick=None, **overrides):
    """Three-class config mirroring the launch preset's shape."""
    specs = [TenantSpec("rt", priority=0),
             TenantSpec("std", priority=1),
             TenantSpec("batch", priority=2)]
    specs = [overrides.get(s.tenant_id, s) for s in specs]
    return QoSConfig(tenants=tuple(specs), default=TenantSpec(priority=2),
                     serve_per_tick=serve_per_tick)


def _conservation(adm):
    for tid, t in adm.stats().items():
        assert t["admitted"] == (t["served"] + t["shed"] + t["queued"]
                                 + t["in_flight"]), (tid, t)


class TestPassthroughIsGlobalFifo:
    @given(ARRIVALS, TAKE_SIZES)
    @settings(max_examples=60, deadline=None)
    def test_arrival_order_exact(self, tenants, takes):
        adm = AdmissionQueue()  # qos=None: the load-bearing default
        for i, tid in enumerate(tenants):
            adm.ingest(_Raw(tenant=tid, tag=i))
        served = []
        for k in takes:
            for rec in adm.take(k):
                adm.mark_served(rec)
                served.append(rec.raw.tag)
        drained = [r.raw.tag for r in adm.take(None)]
        for rec_tag in drained:
            served.append(rec_tag)
        # global FIFO: the concatenation of takes is the arrival prefix
        assert served == list(range(len(served)))
        assert len(adm) == len(tenants) - len(served)
        _conservation(adm)

    @given(ARRIVALS)
    @settings(max_examples=30, deadline=None)
    def test_nothing_shed_ever(self, tenants):
        adm = AdmissionQueue()
        for tid in tenants:
            adm.ingest(_Raw(tenant=tid, client=7))
        adm.expire()
        assert adm.pop_notice(7) is None
        assert all(t["shed"] == 0 for t in adm.stats().values())


class TestQosPreservesPerTenantFifo:
    @given(ARRIVALS, TAKE_SIZES)
    @settings(max_examples=60, deadline=None)
    def test_per_tenant_order(self, tenants, takes):
        adm = AdmissionQueue(qos=_qos())
        for i, tid in enumerate(tenants):
            adm.ingest(_Raw(tenant=tid, tag=i))
        served = {t: [] for t in TENANTS}
        for k in takes + [len(tenants)]:
            for rec in adm.take(k):
                adm.mark_served(rec)
                served[rec.tenant].append(rec.raw.tag)
        # every admitted request was served (no deadline/rate in this mix)
        assert sum(len(v) for v in served.values()) == len(tenants)
        for tid, tags in served.items():
            assert tags == sorted(tags), f"tenant {tid} reordered"
            assert tags == [i for i, t in enumerate(tenants) if t == tid]
        _conservation(adm)


class TestNoStarvation:
    @given(st.lists(st.integers(min_value=0, max_value=2),
                    min_size=2, max_size=3),
           st.integers(min_value=20, max_value=60))
    @settings(max_examples=40, deadline=None)
    def test_share_bounded_below_by_weight(self, priorities, rounds):
        """Keep every class continuously backlogged and count service:
        stride scheduling must give class c at least
        ``floor(rounds * w_c / W) - 2`` dequeues (slack for the entry
        floor) — no class starves however urgent the others."""
        priorities = sorted(set(priorities))
        specs = {p: TenantSpec(f"t{p}", priority=p) for p in priorities}
        adm = AdmissionQueue(qos=QoSConfig(tenants=tuple(specs.values())))
        total_w = sum(s.effective_weight for s in specs.values())
        got = {p: 0 for p in priorities}
        for _ in range(rounds):
            for p in priorities:  # top up: every class always has work
                adm.ingest(_Raw(tenant=f"t{p}"))
            recs = adm.take(1)
            assert len(recs) == 1
            adm.mark_served(recs[0])
            got[int(recs[0].tenant[1:])] += 1
        for p in priorities:
            floor_share = math.floor(
                rounds * specs[p].effective_weight / total_w) - 2
            assert got[p] >= floor_share, (p, got, floor_share)
        _conservation(adm)

    def test_bounded_wait_window(self):
        """While a class stays backlogged, its gap between services never
        exceeds ceil(W / w_c) + 1 dequeues — the stride-scheduler bound."""
        specs = [TenantSpec("t0", priority=0), TenantSpec("t1", priority=1),
                 TenantSpec("t2", priority=2)]
        adm = AdmissionQueue(qos=QoSConfig(tenants=tuple(specs)))
        total_w = sum(s.effective_weight for s in specs)
        waits = {s.tenant_id: 0 for s in specs}
        bound = {s.tenant_id: math.ceil(total_w / s.effective_weight) + 1
                 for s in specs}
        for _ in range(200):
            for s in specs:
                adm.ingest(_Raw(tenant=s.tenant_id))
            rec = adm.take(1)[0]
            adm.mark_served(rec)
            for tid in waits:
                waits[tid] = 0 if tid == rec.tenant else waits[tid] + 1
                assert waits[tid] <= bound[tid], (tid, waits, bound)


class TestDeterministicShed:
    def _run(self, script, deadlines):
        tick = [0]
        adm = AdmissionQueue(
            qos=_qos(rt=TenantSpec("rt", priority=0,
                                   deadline_ticks=deadlines),
                     std=TenantSpec("std", priority=1, rate=1, burst=2),
                     batch=TenantSpec("batch", priority=2, max_queue=2)),
            clock=lambda: tick[0])
        for arrivals in script:
            tick[0] += 1
            for i, tid in enumerate(arrivals):
                adm.ingest(_Raw(tenant=tid, client=100 + i))
            adm.expire()
            for rec in adm.take(1):   # starved server: 1 req/tick capacity
                adm.mark_served(rec)
            _conservation(adm)
        return adm.stats()

    @given(st.integers(min_value=0, max_value=9),
           st.integers(min_value=1, max_value=4))
    @settings(max_examples=25, deadline=None)
    def test_same_script_same_ledger(self, seed, deadlines):
        sched = burst_schedule(12, base=2, burst=6, burst_at=(4,), width=3)
        script = tenant_arrivals(12, TENANTS, sched, seed=seed)
        a, b = self._run(script, deadlines), self._run(script, deadlines)
        assert a == b
        # the overload burst overruns the 1/tick server: SOMETHING shed,
        # and every shed is attributed to a reason (no silent drops)
        shed = sum(t["shed"] for t in a.values())
        reasons = sum(sum(t["shed_reasons"].values()) for t in a.values())
        assert shed == reasons
        assert shed > 0

    def test_rate_shed_is_notified(self):
        tick = [0]
        adm = AdmissionQueue(
            qos=_qos(std=TenantSpec("std", priority=1, rate=1, burst=1)),
            clock=lambda: tick[0])
        tick[0] = 1
        assert adm.ingest(_Raw(tenant="std", client=5)) is not None
        assert adm.ingest(_Raw(tenant="std", client=5)) is None
        assert adm.pop_notice(5) == "rate"
        assert adm.pop_notice(5) is None
        st_ = adm.stats()["std"]
        assert st_["shed_reasons"] == {"rate": 1}
        _conservation(adm)


class TestGenerators:
    def test_zipf_is_deterministic_and_skewed(self):
        a = zipf_tenants(500, TENANTS, seed=3)
        assert a == zipf_tenants(500, TENANTS, seed=3)
        counts = {t: a.count(t) for t in TENANTS}
        assert counts["rt"] > counts["std"] > counts["batch"] > 0

    def test_burst_schedule_shapes(self):
        s = burst_schedule(8, base=1, burst=5, burst_at=(2,), width=3)
        assert s == [1, 1, 5, 5, 5, 1, 1, 1]
        script = tenant_arrivals(8, TENANTS, s, seed=0)
        assert [len(t) for t in script] == s

    def test_percentile_from_hist(self):
        assert percentile_from_hist({}, 0.99) == 0.0
        hist = {1: 50, 2: 49, 100: 1}
        assert percentile_from_hist(hist, 0.5) == 1.0
        assert percentile_from_hist(hist, 0.99) == 2.0  # rank 98.01 of 100
        assert percentile_from_hist(hist, 1.0) == 100.0
