"""Broker: MQTT-style discovery (R3) and failover (R4)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Broker, BrokerError, Caps, topic_matches


class TestTopicMatching:
    def test_exact(self):
        assert topic_matches("/objdetect/mobilev3", "/objdetect/mobilev3")
        assert not topic_matches("/objdetect/mobilev3", "/objdetect/yolov2")

    def test_hash_wildcard(self):
        # the paper's example: subscribe "/objdetect/#"
        assert topic_matches("/objdetect/#", "/objdetect/mobilev3")
        assert topic_matches("/objdetect/#", "/objdetect/yolov2")
        assert topic_matches("/objdetect/#", "/objdetect/a/b/c")
        assert not topic_matches("/objdetect/#", "/posestim/x")

    def test_plus_wildcard(self):
        assert topic_matches("cam/+/rgb", "cam/left/rgb")
        assert not topic_matches("cam/+/rgb", "cam/left/depth")
        assert not topic_matches("cam/+", "cam/left/rgb")

    topic_seg = st.text(alphabet="abcz09", min_size=1, max_size=4)

    @given(st.lists(topic_seg, min_size=1, max_size=4))
    @settings(max_examples=50, deadline=None)
    def test_self_match_and_hash(self, segs):
        topic = "/".join(segs)
        assert topic_matches(topic, topic)
        assert topic_matches("#", topic)
        assert topic_matches("/".join(segs[:-1] + ["+"]), topic)


class TestDiscovery:
    def test_capability_based_connection(self):
        b = Broker()
        b.register("/objdetect/mobilev3", Caps.ANY, "ep1", model="mobilenetv3")
        b.register("/objdetect/yolov2", Caps.ANY, "ep2", model="yolov2")
        found = b.discover("/objdetect/#")
        assert [r.endpoint for r in found] == ["ep1", "ep2"]

    def test_spec_filters(self):
        # servers may declare extra specs ("model and version") for clients
        b = Broker()
        b.register("query/det", Caps.ANY, "a", version=1)
        b.register("query/det", Caps.ANY, "b", version=2)
        assert b.subscribe("query/det", version=2).endpoint == "b"

    def test_no_publisher_raises(self):
        b = Broker()
        with pytest.raises(BrokerError):
            _ = b.subscribe("nothing/here").endpoint


class TestFailover:
    def test_rebind_on_down(self):
        b = Broker()
        r1 = b.register("svc/x", Caps.ANY, "primary")
        r2 = b.register("svc/x", Caps.ANY, "backup")
        sub = b.subscribe("svc/#")
        assert sub.endpoint == "primary"
        b.mark_down(r1)
        assert sub.endpoint == "backup"
        assert sub.failovers == 1

    def test_late_publisher_binds(self):
        b = Broker()
        sub = b.subscribe("svc/#")
        assert sub.current is None
        b.register("svc/x", Caps.ANY, "late")
        assert sub.endpoint == "late"

    def test_unregister_then_empty(self):
        b = Broker()
        r = b.register("svc/x", Caps.ANY, "only")
        sub = b.subscribe("svc/x")
        b.unregister(r)
        with pytest.raises(BrokerError):
            _ = sub.endpoint
