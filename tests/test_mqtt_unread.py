"""Regression tests for ``MqttSrc.unread`` ordering and the scheduler's
burst-surplus re-queue (DESIGN.md §1 'runtime burst draining').

Invariants under test:

* frames handed back via ``unread`` re-emerge at the FRONT of the line, in
  their original order, ahead of anything still queued on the channel;
* an unread frame is never decoded twice — it comes back as the same
  decoded object, and the channel's raw queue is untouched;
* when a burst pulls more frames than it can run (a sibling channel raced
  below the burst size), the surplus decoded frames survive via unread and
  replay first on the next drain.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Broker, StreamBuffer, parse_launch
from repro.core import compression as comp
from repro.runtime import Device, Runtime


def _frame(i: int) -> StreamBuffer:
    return StreamBuffer(tensors=(jnp.full((2, 2), i, jnp.float32),),
                        pts=jnp.int32(i))


def _wired_src(broker: Broker, topic="t", codec="none"):
    """A realized publisher channel + subscribed MqttSrc pair."""
    pub = parse_launch(
        f"appsrc name=in ! mqttsink pub-topic={topic} codec={codec} name=snk")
    sink = pub.elements["snk"].connect(broker)
    pub.realize()
    sub = parse_launch(
        f"mqttsrc sub-topic={topic} codec={codec} name=src ! appsink name=o")
    src = sub.elements["src"].connect(broker)
    sub.realize()
    return pub, sink, src


class TestUnreadOrdering:
    def test_unread_comes_back_front_of_line_in_order(self):
        broker = Broker()
        pub, sink, src = _wired_src(broker)
        for i in range(5):
            sink.apply({}, [_frame(i)])
        a, b = src.pull(), src.pull()
        src.unread([a, b])
        # unread frames first, in original order, then the queued remainder
        got = [int(f.pts) for f in src.pull_burst(5)]
        assert got == [0, 1, 2, 3, 4]

    def test_unread_interleaves_ahead_of_fresh_frames(self):
        broker = Broker()
        pub, sink, src = _wired_src(broker)
        sink.apply({}, [_frame(0)])
        sink.apply({}, [_frame(1)])
        first = src.pull()
        src.unread([first])
        sink.apply({}, [_frame(2)])  # fresh frame arrives behind the unread
        got = [int(f.pts) for f in src.pull_burst(3)]
        assert got == [0, 1, 2]

    def test_unread_frames_never_decoded_twice(self):
        """Decoded objects must round-trip through unread untouched; a raw
        re-queue would run the codec a second time."""
        broker = Broker()
        pub, sink, src = _wired_src(broker, codec="quant8")
        for i in range(3):
            sink.apply({}, [_frame(i)])
        decoded = [src.pull(), src.pull()]
        calls = {"n": 0}
        real_decode = comp.decode

        def counting_decode(buf, codec):
            calls["n"] += 1
            return real_decode(buf, codec)

        src.unread(decoded)
        try:
            comp.decode = counting_decode
            # rebind the module-level name MqttSrc.pull closes over
            import repro.core.pubsub as pubsub
            pubsub.comp.decode = counting_decode
            back = [src.pull(), src.pull()]
        finally:
            comp.decode = real_decode
        assert back[0] is decoded[0] and back[1] is decoded[1]
        assert calls["n"] == 0  # pushed-back frames skip the codec entirely
        assert int(src.pull().pts) == 2  # the queued frame still decodes

    def test_queued_counts_pushback_plus_channel(self):
        broker = Broker()
        pub, sink, src = _wired_src(broker)
        for i in range(4):
            sink.apply({}, [_frame(i)])
        x = src.pull()
        assert src.queued() == 3
        src.unread([x])
        assert src.queued() == 4


class TestBurstSurplusRequeue:
    def _two_source_run(self):
        """Mux over two mqttsrc topics with UNEQUAL backlogs."""
        rt = Runtime(burst=8)
        cam = Device("cam")
        p = parse_launch("""
            testsrc width=4 height=4 name=c1 ! tensor_converter ! mqttsink pub-topic=a name=s1
            testsrc width=4 height=4 name=c2 ! tensor_converter ! mqttsink pub-topic=b name=s2
        """)
        cam.add_pipeline(p, jit=False)
        rt.add_device(cam)
        rt.run(4)  # both topics hold 4 frames
        proc = Device("proc")
        m = parse_launch("""
            mqttsrc sub-topic=a name=sa ! mux.sink_0
            mqttsrc sub-topic=b name=sb ! mux.sink_1
            tensor_mux name=mux ! appsink name=o
        """)
        run = proc.add_pipeline(m, jit=False)
        rt.add_device(proc)
        return rt, run

    def test_surplus_frames_requeue_at_front_not_dropped(self):
        rt, run = self._two_source_run()
        sa = run.pipe.elements["sa"]
        sb = run.pipe.elements["sb"]
        # sb races below the burst size: drain 3 of its 4 queued frames
        for _ in range(3):
            sb.pull()
        # force a 4-frame burst: sa pulls 4, sb only delivers 1 → replay
        # fallback runs 1 frame and unreads sa's surplus 3
        rt._run_burst(run, 4)
        assert run.frames == 1
        assert sa.queued() == 3
        # surplus frames re-emerge first and in order on the next drain
        got = [int(b.pts) for b in sa.pull_burst(3)]
        assert got == sorted(got)

    def test_next_tick_drains_requeued_surplus_in_order(self):
        rt, run = self._two_source_run()
        sb = run.pipe.elements["sb"]
        for _ in range(3):
            sb.pull()
        rt._run_burst(run, 4)
        assert run.frames == 1
        rt.run(3)  # publishers refill topic b; surplus on a replays first
        pts = [int(b.pts) for b in run.sink_log["o"]]
        assert pts == sorted(pts)  # never reordered, never double-served
        assert len(pts) == len(set(pts))
