"""Distributed-path correctness on forged host devices (subprocess so
XLA_FLAGS takes effect before jax init — the main test process stays at 1
device, as required).

* shard_map MoE == dense-path MoE numerics on a real (2,4) mesh (EP and
  intra-expert-TP regimes).
* train/prefill/decode steps lower+compile on a small mesh for a dense and
  an MoE smoke arch (mini dry-run).
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


@pytest.mark.slow
def test_shard_map_moe_matches_dense():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import set_mesh
        from repro.models import ModelConfig
        from repro.models import moe as MOE
        from repro.models.sharding import sharding_rules
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for n_experts in (4, 8):   # 4 -> intra-expert TP, 8 -> EP
            cfg = ModelConfig(name="t", arch_type="moe", n_layers=1,
                              d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                              vocab=64, n_experts=n_experts, top_k=2,
                              d_ff_expert=32, dtype="float32",
                              capacity_factor=float(n_experts))  # no drops
            p = MOE.moe_init(jax.random.PRNGKey(0), cfg)
            x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32))
            y_ref, aux_ref = MOE._apply_moe_dense(p, cfg, x)
            with set_mesh(mesh):
                with sharding_rules(batch="data", __mesh__=mesh):
                    y_sm, aux_sm = jax.jit(
                        lambda p, x: MOE._apply_moe_shard_map(p, cfg, x, mesh)
                    )(p, x)
            np.testing.assert_allclose(np.asarray(y_sm), np.asarray(y_ref),
                                       rtol=2e-4, atol=2e-4)
            # aux is computed per data shard then averaged (GShard-style
            # per-group balance) — statistically close to the global value
            # but not bit-identical
            np.testing.assert_allclose(float(aux_sm), float(aux_ref), rtol=0.2)
            print("moe ok", n_experts)
    """)


@pytest.mark.slow
def test_mini_dryrun_lowers_on_small_mesh():
    _run("""
        import jax
        from dataclasses import replace
        from repro.launch.mesh import set_mesh
        from repro.configs import get_config
        from repro.models.model import build_model
        from repro.launch import steps as ST, shardings as SH

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for arch in ("gemma3-4b", "mixtral-8x22b", "mamba2-130m"):
            cfg = get_config(arch).smoke()
            cfg = replace(cfg, vocab=512)
            model = build_model(cfg)
            stacked = model.supports_stacked
            pshape = ST.eval_params_shape(model, stacked)
            pspec = SH.stacked_param_shardings(cfg, mesh, pshape)
            with set_mesh(mesh):
                # train
                step = ST.make_train_step(model, mesh, stacked=stacked)
                oshape = ST.eval_opt_shape(pshape)
                ospec = ST.opt_shardings(mesh, pspec, oshape)
                import jax.numpy as jnp
                batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
                bspec = SH.batch_shardings(cfg, mesh, batch)
                jax.jit(step, in_shardings=(pspec, ospec, bspec)).lower(
                    pshape, oshape, batch).compile()
                # decode
                dstep = ST.make_decode_step(model, mesh, stacked=stacked)
                cshape = ST.eval_cache_shape(model, 8, 64, stacked)
                cspec = SH.cache_shardings(cfg, mesh, cshape)
                tok = jax.ShapeDtypeStruct((8,), jnp.int32)
                tspec = SH.batch_shardings(cfg, mesh, {"t": tok})["t"]
                jax.jit(dstep, in_shardings=(pspec, tspec, cspec)).lower(
                    pshape, tok, cshape).compile()
            print("lowered", arch)
    """)


@pytest.mark.slow
def test_seq_parallel_ssd_matches_reference():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import set_mesh
        from dataclasses import replace
        from repro.models import ModelConfig
        from repro.models import ssm as SSM
        from repro.models.sharding import sharding_rules

        cfg = ModelConfig(name="s", arch_type="ssm", n_layers=1, d_model=64,
                          n_heads=0, n_kv_heads=0, d_ff=0, vocab=64,
                          layer_pattern="S", ssm_state=16, ssm_head_dim=16,
                          ssm_chunk=8, dtype="float32")
        p = SSM.ssm_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64))
        y_ref = SSM.ssm_train(p, cfg, x)
        _, cache_ref = SSM.ssm_prefill(p, cfg, x)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg_sp = replace(cfg, ssm_seq_parallel=True)
        with set_mesh(mesh):
            with sharding_rules(batch="data", __mesh__=mesh):
                y_sp = jax.jit(lambda p, x: SSM.ssm_train(p, cfg_sp, x))(p, x)
                y_pf, cache_sp = jax.jit(
                    lambda p, x: SSM.ssm_prefill(p, cfg_sp, x))(p, x)
        np.testing.assert_allclose(np.asarray(y_sp), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(cache_sp["h"]),
                                   np.asarray(cache_ref["h"]),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(cache_sp["conv"]),
                                   np.asarray(cache_ref["conv"]),
                                   rtol=1e-4, atol=1e-4)
        print("seq-parallel prefill+train ok")
    """)


@pytest.mark.slow
@pytest.mark.skipif(
    not hasattr(__import__("jax"), "shard_map"),
    reason="partial-manual shard_map subgroups CHECK-fail inside jaxlib "
           "0.4.x's SPMD partitioner (spmd_partitioner.cc:512); needs the "
           "jax>=0.5 manual-axes path")
def test_pp_pod_offload_serve():
    """Pipeline-parallel decode across the pod axis (Fig. 2 at pod scale):
    tokens and caches must match the plain stacked decode."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import set_mesh
        from repro.models import ModelConfig, build_model
        from repro.launch.pp_serve import make_pp_serve_step, pp_applicable
        cfg = ModelConfig(name="t", arch_type="dense", n_layers=4, d_model=64,
                          n_heads=4, n_kv_heads=2, d_ff=128, vocab=97,
                          dtype="float32")
        m = build_model(cfg)
        sp = m.stack_params(m.init(jax.random.PRNGKey(0)))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0, 97)
        lp, cache = m.prefill_stacked(sp, {"tokens": toks}, max_seq=20)
        nxt = jnp.argmax(lp, -1)
        ld_ref, cref = m.decode_step_stacked(sp, nxt, cache)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        assert pp_applicable(m, mesh)
        with set_mesh(mesh):
            tok_out, cpp = jax.jit(make_pp_serve_step(m, mesh))(sp, nxt, cache)
        np.testing.assert_array_equal(np.asarray(tok_out),
                                      np.asarray(jnp.argmax(ld_ref, -1)))
        np.testing.assert_allclose(np.asarray(cpp["groups"][0]["k"]),
                                   np.asarray(cref["groups"][0]["k"]),
                                   atol=1e-5)
        print("pp serve ok")
    """)
