"""Fault-tolerant among-device serving (DESIGN.md §3).

The among-device requirement only matters if serving survives devices
leaving and joining — the normal state of consumer fleets.  These tests
drive the failover fabric with the deterministic chaos harness
(tests/chaoslib.py): scripted kills/revivals at chosen ticks, no
wall-clock, no flakes.

Acceptance contract pinned here (and gated in benchmarks/bench_failover.py):
killing a serving device mid-batch loses ZERO client requests — orphaned
queries re-dispatch to a surviving server and every answer is bitwise what
the fault-free run produces; frames with no live server park and recover
within 2 ticks of a server's (re-)registration.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Broker, BrokerError, Caps, StreamBuffer, TensorSpec, \
    parse_launch
from repro.core.elements import register_model
from repro.runtime import Device, Runtime


@pytest.fixture(scope="module", autouse=True)
def models():
    def init(rng):
        return {"w": jax.random.normal(rng, (12, 4)) * 0.3}

    def apply(p, x):
        return x.astype(jnp.float32).reshape(1, -1) @ p["w"]

    register_model("fosvc", init, apply,
                   out_specs=(TensorSpec((1, 4), "float32"),))


def _server(rt, name="hub", operation="op", **specs):
    """One serving device.  All servers init from PRNGKey(0), so any
    survivor computes bitwise-identical answers — the fault-free twin."""
    dev = Device(name)
    extra = " ".join(f"{k}={v}" for k, v in specs.items())
    ps = parse_launch(
        f"tensor_query_serversrc operation={operation} name=ssrc {extra} ! "
        f"tensor_filter model=fosvc ! tensor_query_serversink name=ssink")
    ps.elements["ssink"].pair_with(ps.elements["ssrc"])
    run = dev.add_pipeline(ps, jit=False)
    rt.add_device(dev)
    return dev, run, ps.elements["ssrc"]


def _clients(rt, n, operation="op", codec="none", prefix="tv"):
    runs = []
    for i in range(n):
        dev = Device(f"{prefix}{i}")
        pc = parse_launch(
            f"testsrc width=2 height=2 ! tensor_converter ! "
            f"tensor_query_client operation={operation} codec={codec} "
            f"name=qc ! appsink name=res")
        runs.append(dev.add_pipeline(pc, jit=False))
        rt.add_device(dev)
    return runs


def _responses(run):
    return [np.asarray(b.tensor) for b in run.sink_log["res"]]


class TestChaosAcceptance:
    def test_mid_batch_server_death_loses_nothing_bitwise(self, chaos):
        """THE acceptance scenario: the serving device dies while this
        tick's batch is mid-gather (3 of 6 requests already stranded on the
        dead endpoint).  The orphans re-dispatch to the survivor within the
        same tick: every client still gets one answer per tick, and every
        answer is bitwise identical to the fault-free run."""
        ticks, n_clients, kill_tick = 6, 6, 3

        # fault-free twin
        rt0 = Runtime(query_batch=8)
        _server(rt0, name="hubA")
        _server(rt0, name="hubB")
        ref_runs = _clients(rt0, n_clients)
        rt0.run(ticks)

        rt = Runtime(query_batch=8)
        devA, runA, ssrcA = _server(rt, name="hubA")
        devB, runB, ssrcB = _server(rt, name="hubB")
        cl_runs = _clients(rt, n_clients)
        harness = chaos(rt)
        harness.kill_server_mid_batch(kill_tick, devA, ssrcA, after_n=3)
        harness.run(ticks)

        assert any("mid-batch" in label and "DISARMED" not in label
                   for _, label in harness.log), "the scripted kill fired"
        for ref, got in zip(ref_runs, cl_runs):
            assert got.frames == ticks          # zero lost requests
            a, b = _responses(ref), _responses(got)
            assert len(a) == len(b) == ticks
            for x, y in zip(a, b):
                np.testing.assert_array_equal(x, y)  # bitwise vs fault-free
        fo = rt.stats()["failover"]
        assert fo["redispatches"] >= 1          # orphans were re-shipped
        assert fo["parked_now"] == 0
        # the survivor picked up all serving from the kill tick onward
        assert runB.frames >= (ticks - kill_tick) * n_clients

    def test_mid_batch_death_with_codec_fused_batches_in_flight(self, chaos):
        """PR-5 regression guard: the fused wire path must not weaken the
        zero-loss contract.  quant8 clients put codec-FUSED batches in
        flight (wire-form requests, decode/encode inside the serving jit);
        the serving device dies mid-gather with 3 requests stranded in wire
        form on the dead endpoint.  The orphans — still encoded — must
        re-dispatch to the survivor, serve through ITS fused executable,
        and answer bitwise what the fault-free twin produces."""
        ticks, n_clients, kill_tick = 6, 6, 3

        rt0 = Runtime(query_batch=8)
        _server(rt0, name="hubA")
        _server(rt0, name="hubB")
        ref_runs = _clients(rt0, n_clients, codec="quant8")
        rt0.run(ticks)

        rt = Runtime(query_batch=8)
        devA, runA, ssrcA = _server(rt, name="hubA")
        devB, runB, ssrcB = _server(rt, name="hubB")
        cl_runs = _clients(rt, n_clients, codec="quant8")
        harness = chaos(rt)
        harness.kill_server_mid_batch(kill_tick, devA, ssrcA, after_n=3)
        harness.run(ticks)

        assert any("mid-batch" in label and "DISARMED" not in label
                   for _, label in harness.log), "the scripted kill fired"
        for ref, got in zip(ref_runs, cl_runs):
            assert got.frames == ticks          # zero lost requests
            a, b = _responses(ref), _responses(got)
            assert len(a) == len(b) == ticks
            for x, y in zip(a, b):
                np.testing.assert_array_equal(x, y)  # bitwise vs fault-free
        fo = rt.stats()["failover"]
        assert fo["redispatches"] >= 1
        assert fo["parked_now"] == 0
        # the batches really were codec-fused on both servers' paths
        qb = rt.stats()["query_batching"]
        assert qb["fused_frames"] == ticks * n_clients
        assert runB.frames >= (ticks - kill_tick) * n_clients

    def test_mid_flush_death_orphans_the_popped_remainder(self, chaos):
        """Same-tick race pin (DESIGN.md §6 satellite): ``mark_down`` lands
        while ``QueryBatcher.flush`` is mid-serve — requests the flush
        already POPPED off the request channel are invisible to the down
        event's purge, so the dead endpoint's remaining groups must go to
        the orphan ledger, never be served by the corpse.  Mixed codecs put
        a group boundary exactly where the kill lands (grouping splits by
        codec): 3 plain answers push, the death fires, and the 3 quant8
        requests still in the batcher's hands orphan + re-dispatch.  The
        3 pushed answers die with the endpoint's purged response channels,
        so ALL six clients re-dispatch — and every answer stays bitwise
        the fault-free twin's."""
        ticks, kill_tick = 6, 3

        rt0 = Runtime(query_batch=8)
        _server(rt0, name="hubA")
        _server(rt0, name="hubB")
        ref_runs = _clients(rt0, 3) + _clients(rt0, 3, codec="quant8",
                                               prefix="q8tv")
        rt0.run(ticks)

        rt = Runtime(query_batch=8)
        devA, runA, ssrcA = _server(rt, name="hubA")
        devB, runB, ssrcB = _server(rt, name="hubB")
        cl_runs = _clients(rt, 3) + _clients(rt, 3, codec="quant8",
                                             prefix="q8tv")
        harness = chaos(rt)
        harness.kill_server_mid_flush(kill_tick, devA, ssrcA,
                                      runA.pipe.elements["ssink"],
                                      after_answers=3)
        harness.run(ticks)

        assert any("mid-flush" in label and "DISARMED" not in label
                   for _, label in harness.log), "the scripted kill fired"
        for ref, got in zip(ref_runs, cl_runs):
            assert got.frames == ticks          # zero lost requests
            a, b = _responses(ref), _responses(got)
            assert len(a) == len(b) == ticks
            for x, y in zip(a, b):
                np.testing.assert_array_equal(x, y)  # bitwise vs fault-free
        # the popped-but-unserved quant8 group hit the flush-orphan ledger
        qb = rt.stats()["query_batching"]
        assert qb["flush_orphans"] == 3
        fo = rt.stats()["failover"]
        assert fo["orphaned_requests"] >= 3
        assert fo["redispatches"] >= 6          # purged answers + orphans
        # hubA answered 2 full ticks plus the pre-kill group, nothing more
        assert runA.frames == (kill_tick - 1) * 6 + 3
        assert runB.frames >= (ticks - kill_tick) * 6

    def test_dead_fleet_parks_then_recovers_within_two_ticks(self, chaos):
        """No live server at all: frames park (no errors, nothing dropped)
        and complete within 2 ticks of the revival's register event."""
        rt = Runtime(query_batch=8)
        dev, _, ssrc = _server(rt)
        cl_runs = _clients(rt, 3)
        harness = chaos(rt)
        harness.kill_server(3, dev, ssrc, crash=True)
        harness.revive_server(6, dev, ssrc)
        harness.run(5)          # ticks 1..5: two served, then parked
        assert all(r.frames == 2 for r in cl_runs)
        assert rt.stats()["failover"]["parked_now"] == 3
        revive_tick = rt.ticks + 1              # revival fires before tick 6
        harness.run(2)
        recovery = rt.ticks - revive_tick
        assert recovery <= 2
        # parked frames resumed; per-tick cadence restored
        assert rt.stats()["failover"]["parked_now"] == 0
        assert all(r.frames >= 3 for r in cl_runs)

    def test_silent_death_detected_by_lease_expiry(self, chaos):
        """crash=False: the device stops heartbeating and serving but sends
        no mark_down — the broker must notice via the lease and fail the
        clients over on its own."""
        rt = Runtime(query_batch=8, lease_ticks=2)
        devA, _, ssrcA = _server(rt, name="hubA")
        devB, runB, ssrcB = _server(rt, name="hubB")
        cl_runs = _clients(rt, 4)
        harness = chaos(rt)
        harness.kill_server(4, devA, ssrcA, crash=False)
        harness.run(10)
        assert rt.broker.expiries >= 1
        assert ssrcA.registration.alive is False
        assert ssrcA.registration.down_reason == "lease-expired"
        # every tick still answered: the binding's data-plane liveness check
        # bridges the gap between the silent death and the lease expiry
        assert all(r.frames == 10 for r in cl_runs)
        assert runB.frames >= 4 * 6

    def test_forced_lease_expiry_fails_over(self, chaos):
        """chaoslib.expire_lease: a stalled device's lease lapses on the
        next broker tick even though the lease horizon is far away — the
        down event re-routes clients with no frame lost."""
        rt = Runtime(query_batch=8, lease_ticks=50)
        devA, _, ssrcA = _server(rt, name="hubA")
        _, runB, _ = _server(rt, name="hubB")
        cl = _clients(rt, 2)
        harness = chaos(rt)
        harness.expire_lease(4, devA, ssrcA.registration)
        harness.run(8)
        assert ssrcA.registration.down_reason == "lease-expired"
        assert rt.broker.expiries == 1
        assert all(r.frames == 8 for r in cl)
        assert runB.frames >= 2 * 5

    def test_leases_never_expire_for_heartbeating_devices(self):
        rt = Runtime(query_batch=8, lease_ticks=1)
        _server(rt)
        cl_runs = _clients(rt, 2)
        rt.run(8)
        assert rt.broker.expiries == 0
        assert all(r.frames == 8 for r in cl_runs)


class TestParkDeadline:
    """``Runtime(park_deadline_ticks=N)`` bounds how long a frame may stay
    parked with no live server (DESIGN.md §6 satellite): at the deadline the
    frame expires into an accounted ``parked_expired`` stat and a
    client-visible error buffer in the pipeline's sink log — EXPLICIT
    degradation instead of an unbounded busy-skip — and the pipeline is
    freed to start fresh frames."""

    def test_expiry_is_accounted_and_client_visible(self, chaos):
        rt = Runtime(query_batch=8, park_deadline_ticks=3)
        dev, _, ssrc = _server(rt)
        cl = _clients(rt, 3)
        harness = chaos(rt)
        harness.kill_server(3, dev, ssrc, crash=True)   # never revived
        harness.run(10)
        fo = rt.stats()["failover"]
        # tick-3 frames parked (t0=3) and expired at tick 6; the freed
        # pipelines parked fresh frames which expired at tick 9 in turn
        assert fo["parked_expired"] == 6
        assert fo["parked_now"] == 3            # the tick-9 generation
        for r in cl:
            assert r.frames == 2                # ticks 1-2 answered
            errs = r.sink_log.get("qc.error", [])
            assert len(errs) == 2               # one per expired frame
            for e in errs:
                assert e.meta["error"] == "park-deadline"
                assert e.meta["operation"] == "op"
                assert e.meta["parked_ticks"] == 3
                assert e.tensors == ()          # an error answer, not data

    def test_recovery_before_deadline_expires_nothing(self, chaos):
        """The deadline must never fire on a frame a revival saved: parked
        at tick 3 with a 5-tick deadline, the server returns at tick 5 —
        the frame completes normally, no error, nothing expired."""
        rt = Runtime(query_batch=8, park_deadline_ticks=5)
        dev, _, ssrc = _server(rt)
        cl = _clients(rt, 3)
        harness = chaos(rt)
        harness.kill_server(3, dev, ssrc, crash=True)
        harness.revive_server(5, dev, ssrc)
        harness.run(8)
        fo = rt.stats()["failover"]
        assert fo["parked_expired"] == 0
        assert fo["parked_now"] == 0
        for r in cl:
            assert "qc.error" not in r.sink_log
            # only the 2-tick outage is missing; the parked frame resumed
            assert r.frames == 8 - 2

    def test_deadline_measures_total_time_parked(self, chaos):
        """Re-parks must not reset the clock: a frame that parks, fails a
        retry, and parks again still expires ``park_deadline_ticks`` after
        it FIRST parked (the retry loop re-parks every tick — a reset would
        make the deadline unreachable)."""
        rt = Runtime(query_batch=8, park_deadline_ticks=4)
        dev, _, ssrc = _server(rt)
        _clients(rt, 1)
        harness = chaos(rt)
        harness.kill_server(3, dev, ssrc, crash=True)
        harness.run(6)                           # parked t0=3, retried 4-6
        assert rt.stats()["failover"]["parked_expired"] == 0
        harness.run(1)                           # tick 7: 7-3 >= 4 → expire
        assert rt.stats()["failover"]["parked_expired"] == 1


class TestResponseChannelLifecycle:
    """Regression: ``QueryServerEndpoint.responses`` channels were never
    RELEASED — death/revival only cleared their queues, so every chaos
    kill/revive epoch (and every client generation) left one orphaned
    Channel per client id on the endpoint, forever.  Liveness transitions
    must purge the dict; steady-state reuse must keep it at one channel per
    live bound client."""

    def test_kill_revive_cycles_keep_channels_bounded(self, chaos):
        n_clients, cycles = 4, 3
        rt = Runtime(query_batch=8)
        devA, _, ssrcA = _server(rt, name="hubA")
        _server(rt, name="hubB")
        cl = _clients(rt, n_clients)
        rt.run(2)
        ep = ssrcA.endpoint
        assert len(ep.responses) == n_clients      # one per bound client
        for c in range(cycles):
            harness = chaos(rt)
            t = rt.ticks
            harness.kill_server(t + 1, devA, ssrcA)
            harness.revive_server(t + 3, devA, ssrcA)
            harness.run(5)
            # the down event released every channel; clients that came back
            # after the revival re-created exactly theirs — no epoch leak
            assert len(ep.responses) <= n_clients
        assert all(r.frames == rt.ticks for r in cl)   # and nothing lost

    def test_down_event_purges_channels_not_just_queues(self):
        rt = Runtime(query_batch=8)
        _, _, ssrc = _server(rt)
        _clients(rt, 3)
        rt.run(1)
        ep = ssrc.endpoint
        assert len(ep.responses) == 3
        ssrc.endpoint.alive = False
        rt.broker.mark_down(ssrc.registration)
        assert len(ep.responses) == 0              # released, not drained

    def test_client_churn_across_outages_does_not_accumulate(self, chaos):
        """Fresh client generations across kill/revive epochs: dead
        generations' channels must not pile up on the endpoint."""
        rt = Runtime(query_batch=8)
        dev, _, ssrc = _server(rt)
        _clients(rt, 2)
        rt.run(1)
        harness = chaos(rt)
        for c in range(3):
            t = rt.ticks
            harness.kill_server(t + 1, dev, ssrc)
            harness.revive_server(t + 2, dev, ssrc)
            harness.run(3)
            _clients(rt, 2)                        # a new generation joins
        rt.run(1)
        # 2 original + 3x2 new = 8 live clients max; without the purge the
        # endpoint would also hold every pre-outage generation's channels
        assert len(ssrc.endpoint.responses) <= 8


class TestCapabilityRouting:
    def test_throughput_ranking_beats_registration_order(self):
        rt = Runtime(query_batch=8)
        _server(rt, name="slowhub", throughput=1)
        _, fast_run, _ = _server(rt, name="fasthub", throughput=8)
        cl = _clients(rt, 3)
        rt.run(2)
        assert fast_run.frames == 6       # all routed to the faster server
        assert all(r.frames == 2 for r in cl)

    def test_codec_support_ranking(self):
        """A quant8 client prefers a server declaring quant8 support over an
        earlier-registered one that declares it cannot."""
        rt = Runtime(query_batch=8)
        _, plain_run, ssrc1 = _server(rt, name="plainhub")
        _, q8_run, ssrc2 = _server(rt, name="q8hub")
        ssrc1.registration.specs["codecs"] = ("none",)
        ssrc2.registration.specs["codecs"] = ("none", "quant8")
        cl = _clients(rt, 2, codec="quant8")
        rt.run(2)
        assert q8_run.frames == 4 and plain_run.frames == 0
        assert all(r.frames == 2 for r in cl)

    def test_load_breaks_ties(self):
        b = Broker()
        r1 = b.register("query/op", Caps.ANY, "busy")
        r2 = b.register("query/op", Caps.ANY, "idle")
        r1.load = 5.0
        assert b.subscribe("query/op").endpoint == "idle"
        r1.load = 0.0
        assert b.subscribe("query/op").endpoint == "busy"  # reg-order tiebreak

    def test_runtime_refreshes_load_from_queue_depth(self):
        rt = Runtime(query_batch=8)
        _, _, ssrc = _server(rt)
        _clients(rt, 2)
        rt.run(1)
        # after a tick the queue has drained back to empty — the declared
        # load tracks the instantaneous backlog
        assert ssrc.registration.load == 0.0


class TestRebindOrdering:
    def test_preferred_down_then_revived_wins_back_exactly_once(self):
        """Regression pin: preferred registration marked down then revived
        must win the binding back exactly once, with no duplicate watch
        event delivery (idempotent mark_down/revive)."""
        b = Broker()
        fast = b.register("svc/a", Caps.ANY, "fast", throughput=10)
        b.register("svc/b", Caps.ANY, "slow", throughput=1)
        events = []
        b.watch(lambda ev, reg: events.append((ev, reg.endpoint)))
        sub = b.subscribe("svc/#")
        assert sub.endpoint == "fast"

        b.mark_down(fast)
        b.mark_down(fast)                      # duplicate: must not re-fire
        assert sub.endpoint == "slow"
        assert sub.failovers == 1

        b.revive(fast)
        b.revive(fast)                         # duplicate: must not re-fire
        assert sub.endpoint == "fast"          # won back ...
        assert sub.failovers == 2              # ... exactly once
        assert events.count(("down", "fast")) == 1
        assert events.count(("register", "fast")) == 1

    def test_equal_rank_newcomer_does_not_steal(self):
        b = Broker()
        b.register("svc/a", Caps.ANY, "first")
        sub = b.subscribe("svc/#")
        b.register("svc/a", Caps.ANY, "second")   # same rank, later reg_id
        assert sub.endpoint == "first"
        assert sub.failovers == 0

    def test_higher_throughput_newcomer_does_steal(self):
        b = Broker()
        b.register("svc/a", Caps.ANY, "weak", throughput=1)
        sub = b.subscribe("svc/#")
        b.register("svc/a", Caps.ANY, "strong", throughput=4)
        assert sub.endpoint == "strong"
        assert sub.failovers == 1

    def test_closed_binding_stops_receiving_events(self):
        b = Broker()
        r = b.register("svc/a", Caps.ANY, "first")
        sub = b.subscribe("svc/#")
        sub.close()
        b.mark_down(r)
        assert sub.current is r                # stale by design after close
        with pytest.raises(BrokerError):
            _ = b.subscribe("svc/#").endpoint


class TestPubSubRebind:
    def test_rebind_preserves_queued_frames(self, chaos):
        """Publisher dies with frames still queued at the subscriber: the
        rebind to the backup publisher must deliver those frames first —
        nothing queued is dropped (DESIGN.md §3 rebind guarantee).  The two
        publishers emit different frame shapes so every consumed frame is
        attributable to its producer."""
        rt = Runtime()
        pubs = []
        for name, w in (("pubA", 2), ("pubB", 4)):
            d = Device(name)
            p = parse_launch(
                f"testsrc width={w} height=2 ! tensor_converter ! "
                f"mqttsink pub-topic=cam/{name} name=snk")
            prun = d.add_pipeline(p, jit=False)
            rt.add_device(d)
            pubs.append((d, prun))
        sub = Device("screen")
        s = parse_launch("mqttsrc sub-topic=cam/# name=src ! appsink name=o")
        sub_run = sub.add_pipeline(s, jit=False)
        rt.add_device(sub)
        src = s.elements["src"]

        rt.run(3)                                  # consumes pubA pts 0..2
        devA, runA = pubs[0]
        # strand two frames: pubA publishes twice more without the consumer
        # running, then dies before they are drained
        rt._run_once(runA)
        rt._run_once(runA)
        assert len(src._rx) == 2
        harness = chaos(rt)
        harness.kill_device(4, devA)
        harness.run(4)
        log = sub_run.sink_log["o"]
        pts_shapes = [(int(b.pts), tuple(b.tensor.shape)) for b in log]
        # pubA's whole stream arrived — including the two frames stranded
        # at its death — in order, before any backup frame (pts are
        # sync-rebased, so assert per-producer ordering, not raw indices)
        a = [(p, s) for p, s in pts_shapes if s == (2, 2, 3)]
        back = pts_shapes[len(a):]
        assert len(a) == 5                       # 3 consumed + 2 stranded
        assert all(s == (2, 2, 3) for _, s in pts_shapes[:5])
        assert [p for p, _ in a] == sorted(p for p, _ in a)
        # then the backup publisher's stream, also in order
        assert back and all(s == (2, 4, 3) for _, s in back)
        assert [p for p, _ in back] == sorted(p for p, _ in back)

    def test_explicit_strand_and_rebind_keeps_frames(self):
        """Unit-level pin of the carry-over: frames sitting in the consumer
        queue when the binding flips publishers are decoded into the
        pushback line in order, ahead of the new publisher's frames."""
        from repro.core import Channel
        from repro.core.pubsub import MqttSrc

        b = Broker()
        chA, chB = Channel(), Channel()
        regA = b.register("cam/a", Caps.ANY, chA)
        b.register("cam/b", Caps.ANY, chB)
        src = MqttSrc(name="src", sub_topic="cam/#").connect(b)
        # bind to A and queue two frames
        chA.push(StreamBuffer(tensors=(jnp.zeros((2, 2)),), pts=jnp.int32(0)))
        assert src.pull().pts == 0
        chA.push(StreamBuffer(tensors=(jnp.ones((2, 2)),), pts=jnp.int32(1)))
        chA.push(StreamBuffer(tensors=(jnp.ones((2, 2)),), pts=jnp.int32(2)))
        chB.push(StreamBuffer(tensors=(jnp.ones((2, 2)),), pts=jnp.int32(9)))
        b.mark_down(regA)      # binding flips to B with 2 frames stranded
        got = [int(src.pull().pts) for _ in range(3)]
        assert got == [1, 2, 9]    # stranded frames first, in order

    def test_queued_counts_carried_frames_on_the_rebind_tick(self):
        """Regression: queued() must resolve BEFORE counting — the rebind
        moves stranded frames into the pushback line, and undercounting
        them would mark the pipeline not-ready for a tick."""
        from repro.core import Channel
        from repro.core.pubsub import MqttSrc

        b = Broker()
        chA, chB = Channel(), Channel()
        regA = b.register("cam/a", Caps.ANY, chA)
        b.register("cam/b", Caps.ANY, chB)
        src = MqttSrc(name="src", sub_topic="cam/#").connect(b)
        assert src.queued() == 0               # attaches to A
        chA.push(StreamBuffer(tensors=(jnp.zeros((2, 2)),), pts=jnp.int32(0)))
        chA.push(StreamBuffer(tensors=(jnp.ones((2, 2)),), pts=jnp.int32(1)))
        b.mark_down(regA)                      # flips to B, frames stranded
        assert src.queued() == 2               # counted on this very call

    def test_winback_rebind_no_duplicates_no_stranding(self):
        """Regression: re-binding BACK to a previously bound publisher must
        reuse its consumer queue — re-attaching would replay the retained
        history a second time (duplicate frames) while the publisher's
        post-revival frames rotted in the orphaned old queue."""
        from repro.core import Channel
        from repro.core.pubsub import MqttSrc

        b = Broker()
        chA, chB = Channel(), Channel()
        regA = b.register("cam/a", Caps.ANY, chA, throughput=2)
        b.register("cam/b", Caps.ANY, chB)
        # retained history on A before the subscriber ever attaches
        chA.push(StreamBuffer(tensors=(jnp.zeros((2, 2)),), pts=jnp.int32(0)))
        src = MqttSrc(name="src", sub_topic="cam/#").connect(b)
        assert int(src.pull().pts) == 0        # replayed once, consumed
        b.mark_down(regA)                      # fail over to B
        chB.push(StreamBuffer(tensors=(jnp.ones((2, 2)),), pts=jnp.int32(10)))
        assert int(src.pull().pts) == 10
        b.revive(regA)                         # throughput: A wins back
        chA.push(StreamBuffer(tensors=(jnp.ones((2, 2)),), pts=jnp.int32(1)))
        assert int(src.pull().pts) == 1        # fresh frame, NOT a replay of 0
        assert src.pull() is None              # and no duplicates after it
        assert len(chA.consumers) == 1         # no consumer leak per rebind
