"""Per-assigned-architecture smoke tests: a REDUCED variant of the same
family (<=3 layers, d_model<=256, <=4 experts) runs one forward and one
train step on CPU, asserting output shapes and no NaNs.  The FULL configs
are exercised only via launch/dryrun.py (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.optim import adamw_init, adamw_update

SEQ = 16
BATCH = 2


def _smoke_batch(cfg, rng):
    batch = {"tokens": jax.random.randint(rng, (BATCH, SEQ), 0, cfg.vocab)}
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(rng, (BATCH, cfg.enc_seq, cfg.d_model))
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(rng, (BATCH, cfg.n_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).smoke()
    assert cfg.n_layers <= 3 and cfg.d_model <= 512
    assert cfg.n_experts <= 4
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))

    logits, aux = m.train_logits(params, batch)
    assert logits.shape[0] == BATCH and logits.shape[-1] == cfg.vocab
    assert not bool(jnp.any(jnp.isnan(logits)))

    # one real optimizer step
    opt = adamw_init(params)
    (loss, _), grads = jax.value_and_grad(
        lambda p: m.loss(p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    new_params, opt, info = adamw_update(params, grads, opt, lr=1e-3)
    assert np.isfinite(float(info["grad_norm"]))
    # params actually moved
    moved = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l[0] - l[1]))),
        jax.tree_util.tree_map(lambda a, b: (a, b), new_params, params), 0.0)
    assert moved > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_serve_step(arch):
    cfg = get_config(arch).smoke()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))
    total = SEQ + (cfg.n_patches if cfg.frontend == "vision" else 0)
    logits, cache = m.prefill(params, batch, max_seq=total + 4)
    assert logits.shape == (BATCH, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    nxt = jnp.argmax(logits, -1)
    logits2, cache = m.decode_step(params, nxt, cache)
    assert logits2.shape == (BATCH, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits2)))
    assert int(cache["pos"]) == total + 1
