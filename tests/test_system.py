"""End-to-end behaviour tests for the among-device AI system.

These reproduce the paper's three application scenarios (Figs. 2, 3, 5) as
complete multi-device deployments on the in-process runtime, plus a short
real training run proving the training substrate learns.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TensorSpec, parse_launch
from repro.core.elements import register_model
from repro.data import make_train_iterator
from repro.models import ModelConfig, build_model
from repro.optim import adamw_init, adamw_update
from repro.runtime import Device, Runtime


@pytest.fixture(scope="module", autouse=True)
def models():
    def init(rng):
        return {"w": jax.random.normal(rng, (768, 8)) * 0.05}

    def apply(p, x):
        return (x.astype(jnp.float32).reshape(1, -1) @ p["w"],)

    register_model("detector", init, apply,
                   out_specs=(TensorSpec((1, 8), "float32"),))


class TestFig2Offloading:
    """TV (no compute) + phone (model): pose-estimation offloading."""

    def test_tv_offloads_to_phone(self):
        rt = Runtime()
        phone = Device("phone")
        srv = parse_launch(
            "tensor_query_serversrc operation=posestimation name=ssrc ! "
            "tensor_filter model=detector ! tensor_query_serversink name=ssink")
        srv.elements["ssink"].pair_with(srv.elements["ssrc"])
        phone.add_pipeline(srv, jit=False)
        rt.add_device(phone)

        tv = Device("tv")
        cli = parse_launch("""
            testsrc width=16 height=16 ! tee name=ts
            ts. queue leaky=2 ! videoconvert ! appsink name=screen
            ts. tensor_converter !
               tensor_query_client operation=posestimation ! appsink name=pose
        """)
        tv.add_pipeline(cli, jit=False)
        rt.add_device(tv)
        rt.run(5)
        run = tv.runs[0]
        assert run.frames == 5
        assert run.last_outputs["pose"].tensor.shape == (1, 8)
        assert run.last_outputs["screen"].tensor.shape == (16, 16, 3)


class TestFig3MultiCamera:
    """Two camera devices + processing device + display device."""

    def test_full_scenario(self):
        rt = Runtime()
        for side in ("left", "right"):
            cam = Device(f"cam_{side}")
            p = parse_launch(
                f"testsrc width=16 height=16 ! tensor_converter ! "
                f"mqttsink pub-topic=cam/{side}")
            cam.add_pipeline(p, jit=False)
            rt.add_device(cam)

        proc = Device("coral")
        pp = parse_launch("""
            mqttsrc sub-topic=cam/left ! tensor_transform mode=arithmetic
              option=typecast:float32 ! tensor_filter model=detector !
              mqttsink pub-topic=edge/inference
        """)
        proc.add_pipeline(pp, jit=False)
        rt.add_device(proc)

        disp = Device("lcd")
        pd = parse_launch("""
            mqttsrc sub-topic=cam/left ! queue ! mux.sink_0
            mqttsrc sub-topic=cam/right ! queue ! mux.sink_1
            tensor_mux name=mux ! appsink name=out
            mqttsrc sub-topic=edge/inference ! appsink name=infer
        """)
        disp.add_pipeline(pd, jit=False)
        rt.add_device(disp)

        rt.run(6)
        out = disp.runs[0]
        assert out.frames >= 4
        assert len(out.last_outputs["out"].tensors) == 2
        assert out.last_outputs["infer"].tensor.shape == (1, 8)


class TestFig5AugmentedWorker:
    """Wearable streams sensors; mobile gates on DETECT then classifies."""

    def test_gated_multimodal(self):
        rt = Runtime()
        wear = Device("watch")
        pw = parse_launch(
            "testsrc width=8 height=4 ! tensor_converter ! "
            "mqttsink pub-topic=wearable/imu")
        wear.add_pipeline(pw, jit=False)
        rt.add_device(wear)

        mobile = Device("phone")
        pm = parse_launch("""
            mqttsrc sub-topic=wearable/imu !
            tensor_transform mode=arithmetic option=typecast:float32,div:255.0 !
            tensor_if threshold=0.5 operator=GE name=gate ! appsink name=decision
        """)
        mobile.add_pipeline(pm, jit=False)
        rt.add_device(mobile)
        rt.run(4)
        dec = mobile.runs[0].last_outputs["decision"]
        assert int(dec.tensors[-1]) in (0, 1)  # gate flag present
        assert mobile.runs[0].frames >= 3


class TestTrainingLearns:
    def test_loss_decreases_on_markov_data(self):
        cfg = ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                          d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                          vocab=128, dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        it = make_train_iterator(vocab=128, global_batch=8, seq=32)

        @jax.jit
        def step(params, opt, tokens):
            (loss, _), grads = jax.value_and_grad(
                lambda p: model.loss(p, {"tokens": tokens}), has_aux=True)(params)
            params, opt, _ = adamw_update(params, grads, opt, lr=3e-3,
                                          weight_decay=0.0)
            return params, opt, loss

        losses = []
        for i in range(60):
            batch = next(it)
            params, opt, loss = step(params, opt, jnp.asarray(batch["tokens"]))
            losses.append(float(loss))
        first, last = np.mean(losses[:5]), np.mean(losses[-5:])
        assert last < first - 0.5, (first, last)
