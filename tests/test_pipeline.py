"""Pipeline graph + parse_launch: the paper's Listing-1/2 style descriptions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Caps, CapsError, Pipeline, TensorSpec, parse_launch
from repro.core.elements import register_model


@pytest.fixture(scope="module", autouse=True)
def models():
    def init(rng):
        return {"w": jax.random.normal(rng, (3, 10)) * 0.1}

    def apply(p, x):
        return jnp.mean(x.reshape(-1, 3), 0) @ p["w"]

    register_model("tinycls", init, apply,
                   out_specs=(TensorSpec((10,), "float32"),))
    # SSD-style two-output detector for the bounding_boxes decoder
    def init_det(rng):
        return {}

    def apply_det(p, x):
        boxes = jnp.array([[0.1, 0.1, 0.5, 0.6], [0.2, 0.3, 0.4, 0.5]])
        scores = jnp.array([0.9, 0.1])
        return boxes, scores

    register_model("tinydet", init_det, apply_det,
                   out_specs=(TensorSpec((2, 4), "float32"),
                              TensorSpec((2,), "float32")))


def _run(pipe, n=1):
    pipe.realize()
    params = pipe.init(jax.random.PRNGKey(0))
    state = pipe.init_state()
    step = jax.jit(pipe.step)
    outs = None
    for _ in range(n):
        outs, state = step(params, state)
    return outs


class TestParseLaunch:
    def test_listing1_style(self):
        """The paper's Listing 1 client pipeline, with a local filter instead
        of the query client (R1: they are drop-in interchangeable)."""
        pipe = parse_launch("""
            v4l2src name=cam ! tee name=ts
            ts. queue leaky=2 ! videoconvert ! mix.sink_1
            ts. videoconvert ! videoscale !
              video/x-raw,width=16,height=16,format=RGB !
              tensor_converter !
              tensor_transform mode=arithmetic option=typecast:float32,add:-127.5,div:127.5 !
              tensor_filter model=tinydet !
              tensor_decoder mode=bounding_boxes option4=64:48 ! queue ! mix.sink_0
            compositor name=mix sink_0::zorder=2 sink_1::zorder=1 ! videoconvert !
              appsink name=display
        """)
        outs = _run(pipe, n=2)
        assert outs["display"].tensor.shape[-1] in (3, 4)

    def test_forward_reference(self):
        pipe = parse_launch("""
            testsrc ! tensor_converter ! mux.sink_0
            testsrc ! tensor_converter ! mux.sink_1
            tensor_mux name=mux ! appsink name=o
        """)
        outs = _run(pipe)
        assert len(outs["o"].tensors) == 2

    def test_caps_mismatch_fails_at_link_time(self):
        pipe = parse_launch("""
            testsrc width=8 height=8 !
            video/x-raw,width=32,height=32,format=RGB ! appsink
        """)
        with pytest.raises(CapsError):
            pipe.realize()

    def test_unknown_factory(self):
        with pytest.raises(KeyError):
            parse_launch("nosuchelement ! appsink")

    def test_demux_src_pads(self):
        pipe = parse_launch("""
            testsrc ! tensor_converter ! mux.sink_0
            testsrc ! tensor_converter ! mux.sink_1
            tensor_mux name=mux ! tensor_demux name=d
            d.src_0 ! appsink name=a
            d.src_1 ! appsink name=b
        """)
        outs = _run(pipe)
        assert outs["a"].tensor.shape == outs["b"].tensor.shape


class TestPipelineSemantics:
    def test_jit_purity_and_state(self):
        pipe = parse_launch("testsrc name=s width=8 height=8 ! appsink name=o")
        pipe.realize()
        params, state = pipe.init(jax.random.PRNGKey(0)), pipe.init_state()
        step = jax.jit(pipe.step)
        o1, state = step(params, state)
        o2, state = step(params, state)
        # deterministic source advances with state
        assert int(o1["o"].pts) != int(o2["o"].pts)

    def test_tensor_transform_arithmetic(self):
        pipe = parse_launch("""
            testsrc width=8 height=8 ! tensor_converter !
            tensor_transform mode=arithmetic option=typecast:float32,add:-127.5,div:127.5 !
            appsink name=o
        """)
        outs = _run(pipe)
        x = np.asarray(outs["o"].tensor)
        assert x.dtype == np.float32
        assert x.min() >= -1.0 and x.max() <= 1.0

    def test_sparse_enc_dec_elements(self):
        pipe = parse_launch("""
            testsrc width=8 height=8 ! tensor_converter !
            tensor_transform mode=arithmetic option=typecast:float32 !
            tensor_sparse_enc max_nnz=256 ! tensor_sparse_dec ! appsink name=o
        """)
        outs = _run(pipe)
        assert outs["o"].tensor.shape == (8, 8, 3)

    def test_tensor_if_gates(self):
        pipe = parse_launch("""
            testsrc width=4 height=4 ! tensor_converter !
            tensor_transform mode=arithmetic option=typecast:float32,div:255.0 !
            tensor_if threshold=2.0 operator=GE ! appsink name=o
        """)
        outs = _run(pipe)
        # normalized frame max < 2.0 -> gate closed -> zeros + flag 0
        assert float(jnp.max(outs["o"].tensors[0])) == 0.0
        assert int(outs["o"].tensors[-1]) == 0

    def test_cycle_detection(self):
        from repro.core.element import element_factory
        p = Pipeline()
        a = element_factory("videoconvert", name="a")
        b = element_factory("videoconvert", name="b")
        p.link(a, b)
        p.link(b, a)
        with pytest.raises(CapsError):
            p.realize()
