"""Substrates: data pipeline determinism/sharding, optimizer, checkpointing,
edge library."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.data import SyntheticLM, make_train_iterator
from repro.edge import pack_buffer, unpack_buffer
from repro.optim import adamw_init, adamw_update, clip_by_global_norm
from repro.optim.schedule import linear_warmup_cosine


class TestData:
    def test_deterministic(self):
        a = next(make_train_iterator(vocab=100, global_batch=4, seq=16))
        b = next(make_train_iterator(vocab=100, global_batch=4, seq=16))
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_shards_partition_global_batch(self):
        """Global batch must be identical regardless of topology."""
        full = next(make_train_iterator(vocab=100, global_batch=8, seq=16))
        parts = [next(make_train_iterator(vocab=100, global_batch=8, seq=16,
                                          shard_index=i, num_shards=4))
                 for i in range(4)]
        stitched = np.concatenate([p["tokens"] for p in parts], 0)
        np.testing.assert_array_equal(full["tokens"], stitched)

    def test_labels_are_shift(self):
        b = next(make_train_iterator(vocab=50, global_batch=2, seq=8))
        np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])

    def test_markov_structure_learnable(self):
        """Bigram entropy must be well below unigram (the corpus has signal)."""
        corpus = SyntheticLM(vocab=64, seed=0, branching=4)
        toks = corpus.sample_tokens(20_000, seed=1)
        # successor entropy: count distinct successors per token
        succ = {}
        for a, b in zip(toks[:-1], toks[1:]):
            succ.setdefault(int(a), set()).add(int(b))
        avg_branch = np.mean([len(s) for s in succ.values()])
        assert avg_branch <= 4.5  # ~branching, << vocab


class TestOptim:
    def test_clip(self):
        g = {"a": jnp.full((10,), 10.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) > 1.0
        n2 = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
        assert abs(n2 - 1.0) < 1e-5

    def test_adamw_reduces_quadratic(self):
        params = {"w": jnp.array([5.0, -3.0])}
        opt = adamw_init(params)
        for _ in range(200):
            g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, opt, _ = adamw_update(params, g, opt, lr=0.1,
                                          weight_decay=0.0)
        assert float(jnp.max(jnp.abs(params["w"]))) < 0.1

    def test_schedule_warmup_then_decay(self):
        lr = linear_warmup_cosine(1e-3, warmup=10, total_steps=100)
        assert float(lr(jnp.int32(0))) == 0.0
        assert abs(float(lr(jnp.int32(10))) - 1e-3) < 1e-9
        assert float(lr(jnp.int32(100))) < 1e-3


class TestCheckpoint:
    def test_roundtrip_with_optstate(self, tmp_path):
        params = {"layers": [{"w": jnp.arange(6.0).reshape(2, 3)},
                             {"w": jnp.ones((3,))}],
                  "emb": jnp.zeros((4, 2), jnp.bfloat16)}
        opt = adamw_init(params)
        d = str(tmp_path)
        save_checkpoint(d, 42, {"params": params, "opt": opt})
        assert latest_step(d) == 42
        step, restored = load_checkpoint(d, like={"params": params, "opt": opt})
        assert step == 42
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a, np.float32),
                                                       np.asarray(b, np.float32)),
            {"params": params, "opt": opt}, restored)

    def test_latest_of_many(self, tmp_path):
        d = str(tmp_path)
        for s in (1, 5, 3):
            save_checkpoint(d, s, {"x": jnp.zeros(1)})
        assert latest_step(d) == 5


class TestEdge:
    @given(st.integers(1, 5), st.integers(1, 20),
           st.sampled_from(["uint8", "float32", "int32"]))
    @settings(max_examples=20, deadline=None)
    def test_wire_roundtrip(self, nt, n, dtype):
        tensors = [np.arange(n * (i + 1), dtype=dtype).reshape(-1)
                   for i in range(nt)]
        data = pack_buffer(tensors, pts=123)
        out, pts = unpack_buffer(data)
        assert pts == 123
        for a, b in zip(tensors, out):
            np.testing.assert_array_equal(a, b)

    def test_edge_sensor_to_pipeline(self):
        """A numpy-only 'RTOS sensor' publishes; an NNStreamer-style pipeline
        subscribes (the NNStreamer-Edge interop scenario)."""
        from repro.core import Broker, parse_launch
        from repro.edge import EdgeSensor
        from repro.runtime import Device, Runtime

        rt = Runtime()
        sensor = EdgeSensor(rt.broker, "sensor/imu")
        sub = Device("hub")
        p = parse_launch("mqttsrc sub-topic=sensor/# ! appsink name=o")
        sub.add_pipeline(p, jit=False)
        rt.add_device(sub)
        for i in range(3):
            sensor.publish([np.full((6,), i, np.float32)], pts=i * 1000)
            rt.tick()
        assert sub.runs[0].frames >= 2

    def test_edge_query_client(self):
        import jax.numpy as jnp
        from repro.core import TensorSpec, parse_launch
        from repro.core.elements import register_model
        from repro.edge import EdgeQueryClient
        from repro.runtime import Device, Runtime

        register_model("edge_svc", lambda r: {},
                       lambda p, x: jnp.sum(x).reshape(1),
                       out_specs=(TensorSpec((1,), "float32"),))
        rt = Runtime()
        dev = Device("hub")
        ps = parse_launch("tensor_query_serversrc operation=sum name=ssrc ! "
                          "tensor_filter model=edge_svc ! "
                          "tensor_query_serversink name=ssink")
        ps.elements["ssink"].pair_with(ps.elements["ssrc"])
        dev.add_pipeline(ps, jit=False)
        rt.add_device(dev)
        client = EdgeQueryClient(rt.broker, "sum")
        out = client.infer([np.ones((4,), np.float32)])
        assert float(out[0][0]) == 4.0
