"""Tenant-aware admission + elastic serving, end to end (DESIGN.md §9).

The §9 contract on the live fabric: tenants tagged at the client ride the
routing meta into every batcher's shared ``AdmissionQueue``; scheduling
changes ORDERING and ADMISSION, never answers; sheds are explicit
client-visible errors with exact per-tenant accounting
(``Runtime.stats()["tenants"]`` asserts the conservation law on every
call); and the fleet elastically scales through ordinary §6
reconfigurations — replica spin-up that dies mid-warm ROLLS BACK on the
same ``target-dead`` path as any planned reconfig.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from chaoslib import Chaos
from repro.core import TensorSpec, parse_launch
from repro.core.admission import QoSConfig, TenantSpec
from repro.core.elements import register_model
from repro.launch.model_serve import three_tier_qos
from repro.runtime import Device, Runtime
from repro.runtime.autoscale import Autoscaler

pytestmark = pytest.mark.qos


@pytest.fixture(scope="module", autouse=True)
def models():
    def init(rng):
        return {"w": jnp.full((12, 4), 0.5)}

    def apply(p, x):
        return x.astype(jnp.float32).reshape(1, -1) @ p["w"]

    register_model("qsvc", init, apply,
                   out_specs=(TensorSpec((1, 4), "float32"),))


def _serve_ps(operation="op"):
    ps = parse_launch(
        f"tensor_query_serversrc operation={operation} name=ssrc ! "
        f"tensor_filter model=qsvc ! tensor_query_serversink name=ssink")
    ps.elements["ssink"].pair_with(ps.elements["ssrc"])
    return ps


def _server(rt, name="hub", operation="op"):
    dev = Device(name)
    ps = _serve_ps(operation)
    dev.add_pipeline(ps, jit=False)
    rt.add_device(dev)
    return dev, ps.elements["ssrc"]


def _client(rt, name="tv", operation="op", tenant=None):
    dev = Device(name)
    tprop = f" tenant={tenant}" if tenant else ""
    pc = parse_launch(
        f"testsrc width=2 height=2 ! tensor_converter ! "
        f"tensor_query_client operation={operation}{tprop} name=qc ! "
        f"appsink name=res")
    dev.add_pipeline(pc, jit=False)
    rt.add_device(dev)
    return dev, pc.elements["qc"]


class TestQosParity:
    def test_qos_on_answers_bitwise_equal(self):
        """Scheduling changes ordering and admission, never answers: an
        uncontended QoS runtime produces byte-identical results to the
        pre-QoS fabric."""
        outs = {}
        for key, qos in (("off", None), ("on", three_tier_qos())):
            rt = Runtime(qos=qos)
            _server(rt)
            cdev, _ = _client(rt, tenant="realtime" if qos else None)
            rt.run(4)
            run = cdev.runs[0]
            assert run.frames == 4
            outs[key] = np.asarray(run.last_outputs["res"].tensor)
        np.testing.assert_array_equal(outs["off"], outs["on"])

    def test_unified_stats_schema_and_conservation(self):
        rt = Runtime(qos=three_tier_qos())
        _server(rt)
        _client(rt, name="tv1", tenant="realtime")
        _client(rt, name="tv2")          # untagged -> "default" ledger
        rt.run(3)
        stats = rt.stats()               # asserts conservation internally
        tenants = stats["tenants"]
        assert set(tenants) >= {"realtime", "default"}
        for t in tenants.values():
            assert set(t) >= {"priority", "admitted", "served", "shed",
                              "queued", "in_flight", "shed_reasons",
                              "p50_ticks", "p99_ticks"}
        assert tenants["realtime"]["served"] == 3
        assert tenants["realtime"]["shed"] == 0
        # the batcher-level schema is unified across all four batchers
        b = next(iter(rt._batchers.values()))
        bs = b.stats()
        assert set(bs) >= {"admitted_requests", "served_requests",
                           "shed_requests", "queued_requests"}


class TestPriorityScheduling:
    def test_realtime_outranks_best_effort_under_starved_server(self):
        """serve_per_tick=1 against two 1-req/tick tenants: stride
        scheduling gives the priority-0 class ~4x the service of the
        priority-2 class (weights 1 vs 1/4) and strictly lower queue
        latency — and NOTHING is silently lost: every admitted request is
        served or still queued/in-flight."""
        qos = QoSConfig(tenants=(TenantSpec("rt", priority=0),
                                 TenantSpec("be", priority=2)),
                        serve_per_tick=1)
        rt = Runtime(qos=qos)
        _server(rt)
        _client(rt, name="tv-rt", tenant="rt")
        _client(rt, name="tv-be", tenant="be")
        rt.run(20)
        t = rt.stats()["tenants"]
        assert t["rt"]["served"] > t["be"]["served"]
        assert t["rt"]["shed"] == 0 and t["be"]["shed"] == 0
        assert t["rt"]["p50_ticks"] <= t["be"]["p50_ticks"]

    def test_rate_shed_is_explicit_client_error(self):
        """A tenant over its token-bucket budget sheds with reason
        ``"rate"`` — booked on the ledger AND answered to the client as an
        explicit error frame (zero silent drops)."""
        qos = QoSConfig(tenants=(
            TenantSpec("metered", priority=1, rate=0.25, burst=1),))
        rt = Runtime(qos=qos)
        _server(rt)
        cdev, _ = _client(rt, name="tv-m", tenant="metered")
        rt.run(8)
        t = rt.stats()["tenants"]["metered"]
        assert t["shed"] > 0
        assert t["shed_reasons"].get("rate", 0) == t["shed"]
        errs = cdev.runs[0].sink_log.get("qc.error", [])
        assert len(errs) == t["shed"]
        assert all(e.meta["error"] == "shed" and e.meta["reason"] == "rate"
                   and e.meta["tenant"] == "metered" for e in errs)
        # conservation with sheds in the mix
        assert t["admitted"] == t["served"] + t["shed"] + t["queued"] + \
            t["in_flight"]


class TestParkedDeadline:
    def test_tenant_deadline_tightens_park_expiry(self):
        """No server at all: frames park.  The tenant's ``deadline_ticks``
        keeps running while parked (parked time IS queue time) and beats a
        looser global ``park_deadline_ticks``; the expiry lands on the
        tenant's shed ledger with reason ``"deadline"``."""
        qos = QoSConfig(tenants=(
            TenantSpec("gold", priority=0, deadline_ticks=3),))
        rt = Runtime(qos=qos, park_deadline_ticks=50)
        cdev, _ = _client(rt, name="tv-g", tenant="gold")
        rt.run(6)
        assert rt.parked_expired >= 1
        t = rt.stats()["tenants"]["gold"]
        assert t["shed_reasons"].get("deadline", 0) == rt.parked_expired
        errs = cdev.runs[0].sink_log.get("qc.error", [])
        assert errs and errs[0].meta["error"] == "park-deadline"
        assert errs[0].meta["parked_ticks"] == 3   # tenant limit, not 50


def _fleet(n_clients=6, serve_per_tick=2, **asc_kw):
    """Overloaded single server + autoscaler managing topic query/op."""
    qos = QoSConfig(serve_per_tick=serve_per_tick)
    rt = Runtime(qos=qos)
    _server(rt)
    clients = [_client(rt, name=f"tv{i}")[0] for i in range(n_clients)]
    asc = Autoscaler(rt, "query/op", lambda i: _serve_ps(),
                     high_load=3.0, low_load=0.5, max_replicas=3,
                     min_replicas=1, cooldown_ticks=3, warm_ticks=1,
                     **asc_kw)
    return rt, clients, asc


class TestAutoscale:
    def test_scale_up_rebalances_and_scale_down_drains_zero_loss(self):
        """The full elastic loop: sustained overload (6 req/tick against a
        2/tick-capacity replica) drives queue depth up -> the broker's
        scaling signal crosses threshold -> replicas grow as §6 reconfigs
        and load rebalances across them; when traffic stops, drained idle
        replicas are REMOVED as §6 reconfigs with zero loss — every
        admitted request was served, none shed, no error frames."""
        rt, clients, asc = _fleet()
        rt.run(20)
        assert asc.scale_ups >= 1
        sig = rt.broker.scaling_signal("query/op")["query/op"]
        assert sig["replicas"] == 1 + len(asc.replicas) >= 2
        # load rebalanced: the new replicas actually served requests
        replica_served = sum(
            sum(t["served"] for t in
                rt._batchers[e.endpoint.endpoint_id].tenant_stats().values())
            for rep in asc.replicas
            for e in rep["run"].pipe.elements.values()
            if hasattr(e, "endpoint") and hasattr(e.endpoint, "requests"))
        assert replica_served > 0
        served_before = sum(c.runs[0].frames for c in clients)
        assert served_before > 0

        for c in clients:               # traffic stops; fleet drains
            c.alive = False
        rt.run(25)
        assert asc.scale_downs >= 1
        t = rt.stats()["tenants"]["default"]
        assert t["shed"] == 0 and t["queued"] == 0 and t["in_flight"] == 0
        assert t["admitted"] == t["served"]
        for c in clients:               # zero loss: no error frames ever
            assert not c.runs[0].sink_log.get("qc.error")

    def test_replica_killed_mid_scale_up_rolls_back(self):
        """The §9 chaos pin: the device hosting a half-warmed replica dies
        -> the grow reconfig rolls back on the ordinary ``target-dead``
        path, the fleet keeps serving on the survivor, and the autoscaler
        simply tries again after cooldown."""
        rt, clients, asc = _fleet()
        asc.warm_ticks = 4              # wide warm window to die inside
        chaos = Chaos(rt)
        killed = []

        def kill_pending():
            p = asc._pending
            if p is not None and p["kind"] == "up" and not killed:
                p["device"].alive = False
                killed.append(rt.ticks)
        for t in range(2, 12):
            chaos.at(t, kill_pending, label=None)
        chaos.run(30)
        assert killed, "scale-up never started"
        assert asc.rollbacks >= 1
        assert all(not r["device"].alive or r["run"].retired is False
                   for r in asc.replicas)
        # the fleet survived: clients kept getting answers after the kill
        assert sum(c.runs[0].frames for c in clients) > 0
        log = [row for row in rt.reconfig.log if row[2] == "rolled_back"]
        assert log and log[0][3] == "target-dead"
