"""Model-family correctness: every mixer family's decode path must match the
teacher-forced oracle, and stacked (scanned) layout must equal list layout."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, build_model

SEQ = 16


def _roundtrip(cfg, extra=None, cache_extra=8):
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, SEQ), 0,
                                          cfg.vocab)}
    if extra:
        batch.update(extra)
    loss, _ = jax.jit(m.loss)(params, batch)
    assert np.isfinite(float(loss))
    total = SEQ + (cfg.n_patches if cfg.frontend == "vision" else 0)
    logits_pf, cache = m.prefill(params, batch, max_seq=total + cache_extra)
    nxt = jnp.argmax(logits_pf, -1)
    logits_d, _ = m.decode_step(params, nxt, cache)
    b2 = dict(batch)
    b2["tokens"] = jnp.concatenate([batch["tokens"], nxt[:, None]], 1)
    logits_t, _ = m.train_logits(params, b2)
    scale = float(jnp.max(jnp.abs(logits_t[:, -1]))) + 1e-6
    err = float(jnp.max(jnp.abs(logits_d - logits_t[:, -1]))) / scale
    assert err < 1e-2, f"decode vs oracle rel err {err}"
    return m, params, batch


FAMILIES = {
    "dense_gqa_bias": ModelConfig(
        name="t", arch_type="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=97, qkv_bias=True, layer_pattern="LG",
        window=8, dtype="float32"),
    "moe_swa": ModelConfig(
        name="t", arch_type="moe", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=97, n_experts=4, top_k=2,
        d_ff_expert=64, layer_pattern="L", window=8, capacity_factor=2.0,
        dtype="float32"),
    "mla_moe_shared": ModelConfig(
        name="t", arch_type="moe", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=97, mla=True, kv_lora_rank=32,
        q_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        n_experts=4, top_k=2, n_shared_experts=1, d_ff_expert=32,
        first_dense=1, capacity_factor=2.0, dtype="float32"),
    "ssm_mamba2": ModelConfig(
        name="t", arch_type="ssm", n_layers=2, d_model=64, n_heads=0,
        n_kv_heads=0, d_ff=0, vocab=97, layer_pattern="S", ssm_state=16,
        ssm_head_dim=16, ssm_chunk=8, dtype="float32"),
    "hybrid_rglru": ModelConfig(
        name="t", arch_type="hybrid", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=1, d_ff=128, vocab=97, layer_pattern="RRL", window=8,
        lru_width=64, dtype="float32"),
    "partial_rope_layernorm": ModelConfig(
        name="t", arch_type="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=97, rope_frac=0.25, norm="layernorm",
        dtype="float32"),
}


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_family_decode_oracle(family):
    _roundtrip(FAMILIES[family])


def test_whisper_encdec():
    cfg = ModelConfig(name="t", arch_type="audio", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab=97,
                      enc_dec=True, n_enc_layers=2, enc_seq=12, max_seq=40,
                      mlp_glu=False, act="gelu", norm="layernorm",
                      dtype="float32")
    frames = jax.random.normal(jax.random.PRNGKey(3), (2, 12, 64))
    _roundtrip(cfg, extra={"frames": frames})


def test_vlm_patch_prefix():
    cfg = ModelConfig(name="t", arch_type="vlm", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=97,
                      frontend="vision", n_patches=8, dtype="float32")
    patches = jax.random.normal(jax.random.PRNGKey(4), (2, 8, 64))
    _roundtrip(cfg, extra={"patches": patches})


@pytest.mark.parametrize("family", ["dense_gqa_bias", "mla_moe_shared",
                                    "hybrid_rglru", "ssm_mamba2"])
def test_stacked_equals_list(family):
    cfg = FAMILIES[family]
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    sp = m.stack_params(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, SEQ), 0, cfg.vocab)
    l1, _ = m.loss(params, {"tokens": toks})
    l2, _ = m.loss_stacked(sp, {"tokens": toks})
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    # prefill+decode parity
    lp, cache = m.prefill_stacked(sp, {"tokens": toks}, max_seq=SEQ + 8)
    lp2, _ = m.prefill(params, {"tokens": toks}, max_seq=SEQ + 8)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lp2), rtol=1e-4,
                               atol=1e-4)
    nxt = jnp.argmax(lp, -1)
    ld, _ = m.decode_step_stacked(sp, nxt, cache)
    toks2 = jnp.concatenate([toks, nxt[:, None]], 1)
    lt, _ = m.train_logits(params, {"tokens": toks2})
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lt[:, -1]),
                               rtol=1e-3, atol=1e-3)


def test_remat_does_not_change_loss():
    cfg = FAMILIES["dense_gqa_bias"]
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, SEQ), 0, cfg.vocab)
    l1, _ = m.loss(params, {"tokens": toks})
    l2, _ = m.loss(params, {"tokens": toks}, remat=True)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_sliding_window_masks_history():
    """A window-L model must ignore tokens older than the window."""
    cfg = ModelConfig(name="t", arch_type="dense", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=13,
                      layer_pattern="L", window=4, dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, 13)
    t2 = t1.at[:, 0].set((t1[0, 0] + 1) % 13)  # mutate far-history token
    l1, _ = m.train_logits(params, {"tokens": t1})
    l2, _ = m.train_logits(params, {"tokens": t2})
    np.testing.assert_allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]),
                               atol=1e-5)


def test_int8_kv_cache_decode_close_to_exact():
    """kv_cache_quant: pure-decode path with int8 cache tracks the exact
    teacher-forced logits within quantization noise."""
    from dataclasses import replace
    cfg = FAMILIES["dense_gqa_bias"]
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0, cfg.vocab)
    mq = build_model(replace(cfg, kv_cache_quant=True))
    cq = mq.init_cache(2, 24)
    for i in range(17):
        lq, cq = mq.decode_step(params, toks[:, i], cq)
    lt, _ = m.train_logits(params, {"tokens": toks})
    scale = float(jnp.max(jnp.abs(lt[:, -1]))) + 1e-9
    err = float(jnp.max(jnp.abs(lq - lt[:, -1]))) / scale
    assert err < 0.05, err


def test_flash_attn_production_path_matches_einsum():
    from dataclasses import replace
    cfg = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=97,
                      dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, 97)
    l1, _ = m.train_logits(params, {"tokens": toks})
    m2 = build_model(replace(cfg, use_flash_attn=True))
    l2, _ = m2.train_logits(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=1e-4, rtol=1e-4)


def test_perf_variant_numerics_mla():
    """mla_fused_qk + attn_additive_mask preserve MLA numerics."""
    from dataclasses import replace
    cfg = FAMILIES["mla_moe_shared"]
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, SEQ), 0, cfg.vocab)
    l1, _ = m.train_logits(params, {"tokens": toks})
    m2 = build_model(replace(cfg, mla_fused_qk=True, attn_additive_mask=True))
    l2, _ = m2.train_logits(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=1e-4, rtol=1e-4)
