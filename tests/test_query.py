"""Query protocol (paper §4.2.2): transparent offloading, multi-client
routing, MQTT-hybrid failover vs TCP-raw none."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Broker, BrokerError, StreamBuffer, TensorSpec,
                        parse_launch)
from repro.core.elements import register_model
from repro.core.query import (QueryTransport, TensorQueryClient,
                              TensorQueryServerSink, TensorQueryServerSrc)
from repro.runtime import Device, Runtime


@pytest.fixture(scope="module", autouse=True)
def models():
    def init(rng):
        return {"w": jnp.full((12, 4), 0.5)}

    def apply(p, x):
        return x.astype(jnp.float32).reshape(1, -1) @ p["w"]

    register_model("svc", init, apply, out_specs=(TensorSpec((1, 4), "float32"),))


def _server(rt, name="hub", operation="op"):
    dev = Device(name)
    ps = parse_launch(
        f"tensor_query_serversrc operation={operation} name=ssrc ! "
        f"tensor_filter model=svc ! tensor_query_serversink name=ssink")
    ps.elements["ssink"].pair_with(ps.elements["ssrc"])
    dev.add_pipeline(ps, jit=False)
    rt.add_device(dev)
    return dev, ps.elements["ssrc"]


def _client(rt, name="tv", operation="op", transport="hybrid"):
    dev = Device(name)
    pc = parse_launch(
        f"testsrc width=2 height=2 ! tensor_converter ! "
        f"tensor_query_client operation={operation} transport={transport} name=qc ! "
        f"appsink name=res")
    dev.add_pipeline(pc, jit=False)
    rt.add_device(dev)
    return dev, pc.elements["qc"]


class TestOffloading:
    def test_roundtrip(self):
        rt = Runtime()
        _server(rt)
        cdev, _ = _client(rt)
        rt.run(2)
        run = cdev.runs[0]
        assert run.frames == 2
        assert run.last_outputs["res"].tensor.shape == (1, 4)

    def test_multi_client_routing(self):
        """serversrc tags client ids; serversink routes answers back (paper:
        'tensor_query_serversrc tags a client ID to the stream metadata')."""
        rt = Runtime()
        _server(rt)
        c1, q1 = _client(rt, name="tv1")
        c2, q2 = _client(rt, name="tv2")
        rt.run(3)
        assert c1.runs[0].frames == 3
        assert c2.runs[0].frames == 3
        assert q1.client_id != q2.client_id

    def test_results_match_local_filter(self):
        """R1: query client is a drop-in replacement for tensor_filter."""
        rt = Runtime()
        _server(rt)
        cdev, _ = _client(rt)
        rt.run(1)
        remote = np.asarray(cdev.runs[0].last_outputs["res"].tensor)

        local = parse_launch(
            "testsrc width=2 height=2 ! tensor_converter ! "
            "tensor_filter model=svc ! appsink name=res")
        local.realize()
        params, state = local.init(jax.random.PRNGKey(0)), local.init_state()
        outs, _ = local.step(params, state)
        np.testing.assert_allclose(remote, np.asarray(outs["res"].tensor),
                                   rtol=1e-6)


class TestFailover:
    def test_hybrid_fails_over_to_second_server(self):
        rt = Runtime()
        d1, ssrc1 = _server(rt, name="hub1")
        d2, ssrc2 = _server(rt, name="hub2")
        cdev, qc = _client(rt)
        rt.run(1)
        assert qc.binding.endpoint is ssrc1.endpoint
        # hub1 dies mid-stream
        ssrc1.endpoint.alive = False
        rt.broker.mark_down(ssrc1.registration)
        rt.run(2)
        assert qc.binding.endpoint is ssrc2.endpoint
        assert cdev.runs[0].frames == 3

    def test_tcp_raw_has_no_failover(self):
        """The paper keeps TCP-raw as the fast-but-fragile baseline (fails
        R3/R4)."""
        broker = Broker()
        ssrc = TensorQueryServerSrc(operation="op")
        client = TensorQueryClient(operation="op", transport="tcp")
        client.connect_direct(ssrc.endpoint)
        ssrc.endpoint.alive = False
        with pytest.raises(BrokerError):
            client.send_query(StreamBuffer(tensors=(jnp.zeros((2, 2)),)))

    def test_spec_selection(self):
        """Clients choose by declared server specs ('model and version')."""
        broker = Broker()
        s1 = TensorQueryServerSrc(operation="det", model="mobilenetv3")
        s1.connect(broker)
        s2 = TensorQueryServerSrc(operation="det", model="yolov2")
        s2.connect(broker)
        c = TensorQueryClient(operation="det", require_model="yolov2")
        c.connect(broker)
        assert c._endpoint() is s2.endpoint
