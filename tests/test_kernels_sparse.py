"""Pallas block-COO sparse enc/dec kernels vs oracle; roundtrip + capacity
semantics (hypothesis sweeps)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


def _sparse_input(n, density, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (n,))
    keep = jax.random.uniform(k2, (n,)) < density
    return jnp.where(keep, x, 0.0)


@given(st.integers(1, 2000), st.floats(0.01, 0.5), st.integers(0, 2 ** 30))
@settings(max_examples=25, deadline=None)
def test_enc_matches_ref(n, density, seed):
    x = _sparse_input(n, density, seed)
    cap = max(1, int(n * 0.6))
    v, i, nnz = ops.sparse_enc(x, cap, 0.0)
    vr, ir, nnzr = ref.sparse_enc_ref(x, cap, 0.0)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    assert int(nnz) == int(nnzr)


@given(st.integers(1, 1500), st.integers(0, 2 ** 30))
@settings(max_examples=20, deadline=None)
def test_roundtrip_under_capacity(n, seed):
    # density low enough that nothing is dropped -> exact reconstruction
    x = _sparse_input(n, 0.15, seed)
    v, i, nnz = ops.sparse_enc(x, cap=n, threshold=0.0)
    y = ops.sparse_dec(v, i, nnz, n)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)


def test_dec_matches_ref():
    x = _sparse_input(3000, 0.2, 7)
    v, i, nnz = ops.sparse_enc(x, cap=3000, threshold=0.0)
    y_k = ops.sparse_dec(v, i, nnz, 3000)
    y_r = ref.sparse_dec_ref(v, i, nnz, 3000)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), atol=1e-6)


def test_threshold_drops_small_values():
    x = jnp.array([0.05, -0.5, 0.2, -0.01] * 200)
    v, i, nnz = ops.sparse_enc(x, cap=800, threshold=0.1)
    y = ops.sparse_dec(v, i, nnz, 800)
    expected = jnp.where(jnp.abs(x) > 0.1, x, 0.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(expected), atol=1e-6)


def test_capacity_truncation_keeps_first_per_block():
    # all-ones: per-block capacity keeps the first kb entries of each block
    n = 1024  # 2 blocks of 512
    x = jnp.ones((n,))
    v, i, nnz = ops.sparse_enc(x, cap=256, threshold=0.0)  # kb=128/block
    vr, ir, nnzr = ref.sparse_enc_ref(x, 256, 0.0)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr))
    assert int(nnz) == int(nnzr) == 256
    y = ops.sparse_dec(v, i, nnz, n)
    # first kb of each 512-block survive
    assert float(y[0]) == 1.0 and float(y[511]) == 0.0
    assert float(y[512]) == 1.0 and float(y[1023]) == 0.0


def test_wire_bytes_accounting():
    from repro.core.buffers import SparsePayload
    x = _sparse_input(1000, 0.1, 3)
    v, i, nnz = ops.sparse_enc(x, cap=250, threshold=0.0)
    sp = SparsePayload(values=v, indices=i, nnz=nnz, dense_shape=(1000,))
    dense_bytes = 1000 * 4
    assert sp.wire_nbytes < dense_bytes
