import os
import sys

# src-layout import path (tests run as `PYTHONPATH=src pytest tests/`, this
# makes plain `pytest` work too).  NOTE: no XLA_FLAGS at THIS level —
# tests/sharding/conftest.py forges 8 host devices for the tier-1 run (the
# sharded serve path needs a real data axis) and launch/dryrun.py forges
# 512 in a subprocess; benches run outside pytest and see the host as-is.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Property tests use hypothesis; when it isn't installed fall back to the
# deterministic vendored shim (tests/_vendor/hypothesis) so the suite still
# collects and runs everywhere.  The real package wins when present.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_vendor"))

import pytest  # noqa: E402


def pytest_collection_modifyitems(config, items):
    """Auto-skip ``multidevice`` tests when the jax backend has fewer than 2
    devices (tests/sharding/conftest.py normally forges 8 before the backend
    initializes; a narrowed run that initialized jax first skips cleanly
    instead of asserting on a 1-device mesh)."""
    marked = [it for it in items if it.get_closest_marker("multidevice")]
    if not marked:
        return
    import jax
    if len(jax.devices()) >= 2:
        return
    skip = pytest.mark.skip(
        reason="needs >=2 jax devices (XLA_FLAGS="
               "--xla_force_host_platform_device_count=8)")
    for it in marked:
        it.add_marker(skip)


@pytest.fixture
def chaos():
    """Factory for the deterministic chaos harness (tests/chaoslib.py):
    ``harness = chaos(rt)`` then schedule faults and ``harness.run(n)``."""
    from chaoslib import Chaos
    return Chaos
