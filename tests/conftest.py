import os
import sys

# src-layout import path (tests run as `PYTHONPATH=src pytest tests/`, this
# makes plain `pytest` work too).  NOTE: no XLA_FLAGS here — smoke tests and
# benches must see 1 device; only launch/dryrun.py forges 512.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Property tests use hypothesis; when it isn't installed fall back to the
# deterministic vendored shim (tests/_vendor/hypothesis) so the suite still
# collects and runs everywhere.  The real package wins when present.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_vendor"))

import pytest  # noqa: E402


@pytest.fixture
def chaos():
    """Factory for the deterministic chaos harness (tests/chaoslib.py):
    ``harness = chaos(rt)`` then schedule faults and ``harness.run(n)``."""
    from chaoslib import Chaos
    return Chaos
