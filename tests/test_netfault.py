"""Adversarial network fabric (DESIGN.md §10).

Every earlier chaos scenario kills *devices*; the transport between them
stayed perfect.  This file makes the transport itself the adversary: a
:class:`FaultFabric` installs deterministic lossy links (drop, duplicate,
corrupt, reorder, delay, scripted partition windows) on the query fabric,
and the delivery layer (``Runtime(delivery=DeliveryPolicy())``) must turn
at-least-once + idempotent dedup into EFFECTIVELY-ONCE.  The acceptance
contract pinned here:

* under every scripted fault class, at batch 1, 4 and 8, every answer a
  client receives is bitwise what the fault-free twin produces — plain
  queries AND mid-generation §7/§8 streams, where a duplicated or
  replayed decode hop must not double-advance a slot;
* zero silent loss: the per-link message conservation law ``sent ==
  accepted + dropped_by_fault + rejected_corrupt + deduped + in_flight +
  overflow_drops + purged`` balances exactly, every scenario;
* lease expiry under a CONTROL-plane partition is *suspicion*, not
  declared death: clients fail over, and the heal wins the registration
  back without double-serving anything the dedup window already settled.

The 200-tick lossy soak (5% drop, 2% dup, delay jitter, one 20-tick
partition + heal, streams live) rides ``-m soak``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from chaoslib import lossy_endpoint
from repro.core import Channel, StreamBuffer, TensorSpec, parse_launch
from repro.core.batching import StagedStreamingBatcher
from repro.core.elements import register_model
from repro.core.netfault import (DeliveryGuard, DeliveryPolicy, FaultFabric,
                                 FaultPolicy, checksum, stamp)
from repro.launch import model_serve as ms
from repro.runtime import Device, Runtime

pytestmark = pytest.mark.netchaos

DELIVERY = DeliveryPolicy()


# -- harness ------------------------------------------------------------------

@pytest.fixture(scope="module", autouse=True)
def models():
    def init(rng):
        return {"w": jax.random.normal(rng, (12, 4)) * 0.3}

    def apply(p, x):
        return x.astype(jnp.float32).reshape(1, -1) @ p["w"]

    register_model("nfsvc", init, apply,
                   out_specs=(TensorSpec((1, 4), "float32"),))


def _server(rt, name="hub", operation="op"):
    """All servers init from PRNGKey(0): any survivor computes bitwise-
    identical answers — the fault-free twin."""
    dev = Device(name)
    ps = parse_launch(
        f"tensor_query_serversrc operation={operation} name=ssrc ! "
        f"tensor_filter model=nfsvc ! tensor_query_serversink name=ssink")
    ps.elements["ssink"].pair_with(ps.elements["ssrc"])
    run = dev.add_pipeline(ps, jit=False)
    rt.add_device(dev)
    return dev, run, ps.elements["ssrc"]


def _clients(rt, n, operation="op", prefix="tv"):
    runs = []
    for i in range(n):
        dev = Device(f"{prefix}{i}")
        pc = parse_launch(
            f"testsrc width=2 height=2 ! tensor_converter ! "
            f"tensor_query_client operation={operation} codec=none "
            f"name=qc ! appsink name=res")
        runs.append(dev.add_pipeline(pc, jit=False))
        rt.add_device(dev)
    return runs


def _responses(run):
    return [np.asarray(b.tensor) for b in run.sink_log.get("res", [])]


def _assert_prefix_bitwise(ref_runs, got_runs, min_answers):
    """Faults stretch the answer cadence (retransmits wait out backoff),
    never the answer VALUES or their per-client order: each lossy run's
    answer stream must be an exact bitwise prefix of the fault-free
    twin's, and long enough to prove liveness."""
    for ref, got in zip(ref_runs, got_runs):
        a, b = _responses(ref), _responses(got)
        assert len(b) >= min_answers, \
            f"liveness: only {len(b)} answers, wanted >= {min_answers}"
        assert len(b) <= len(a)
        for j, (x, y) in enumerate(zip(a, b)):
            np.testing.assert_array_equal(x, y, err_msg=f"answer {j}")


def _buf(i, meta=None):
    return StreamBuffer(tensors=(np.full((4,), i, np.float32),),
                        pts=np.int64(i), meta=dict(meta or {}))


# -- the fault model, unit level ----------------------------------------------

class TestFaultLink:
    def test_same_seed_same_schedule(self):
        """Determinism is the whole game: two links with the same policy
        must inject the identical fault schedule — counters and the
        surviving frame sequence both."""
        pol = FaultPolicy(seed=3, drop=0.2, dup=0.15, corrupt=0.1)
        runs = []
        for _ in range(2):
            fabric = FaultFabric()
            ch = Channel(capacity=256)
            link = fabric.install(ch, pol)
            for i in range(60):
                ch.push(stamp(_buf(i), (1, i)))
            runs.append(([int(b.pts) for b in ch.q], link.stats()))
            fabric.uninstall(ch)
        assert runs[0] == runs[1]

    def test_fault_bands_are_disjoint(self):
        """One uniform draw per frame, carved into disjoint bands: turning
        ON duplication must not perturb which frames drop."""
        def dropped_pts(pol):
            fabric = FaultFabric()
            ch = Channel(capacity=256)
            fabric.install(ch, pol)
            for i in range(80):
                ch.push(_buf(i))
            survivors = {int(b.pts) for b in ch.q}
            fabric.uninstall(ch)
            return set(range(80)) - survivors

        assert dropped_pts(FaultPolicy(seed=9, drop=0.25)) == \
            dropped_pts(FaultPolicy(seed=9, drop=0.25, dup=0.25))

    def test_partition_window_is_tick_scripted(self):
        fabric = FaultFabric()
        ch = Channel(capacity=256)
        link = fabric.install(ch, FaultPolicy(partitions=((2, 5),)))
        for t in range(1, 7):
            fabric.step(t)
            ch.push(_buf(t))
        assert [int(b.pts) for b in ch.q] == [1, 5, 6]
        assert link.dropped_fault == 3
        fabric.assert_conservation()      # eaten frames are accounted

    def test_delay_holds_until_due_tick(self):
        fabric = FaultFabric()
        ch = Channel(capacity=256)
        link = fabric.install(ch, FaultPolicy(seed=1, delay=1.0,
                                              delay_ticks=(2, 2)))
        fabric.step(1)
        ch.push(_buf(0))
        assert len(ch) == 0 and link.in_flight() == 1
        fabric.assert_conservation()      # held frame counts as in flight
        fabric.step(2)
        assert len(ch) == 0               # not due yet (held 2 ticks)
        fabric.step(3)
        assert [int(b.pts) for b in ch.q] == [0]
        fabric.assert_conservation()

    def test_reorder_swaps_adjacent_frames(self):
        fabric = FaultFabric()
        ch = Channel(capacity=256)
        link = fabric.install(ch, FaultPolicy(seed=1, reorder=1.0))
        ch.push(_buf(0))
        ch.push(_buf(1))
        assert [int(b.pts) for b in ch.q] == [1, 0]
        assert link.reordered == 1
        # a straggler with no partner flushes on the next fabric step
        ch.push(_buf(2))
        assert [int(b.pts) for b in ch.q] == [1, 0]
        fabric.step(1)
        assert [int(b.pts) for b in ch.q] == [1, 0, 2]
        fabric.assert_conservation()

    def test_corruption_never_mutates_the_senders_buffer(self):
        """The sender retransmits the SAME payload object on timeout — a
        flip that mutated it in place would corrupt every retry too."""
        fabric = FaultFabric()
        ch = Channel(capacity=256)
        fabric.install(ch, FaultPolicy(seed=5, corrupt=1.0))
        src = _buf(7)
        original = np.asarray(src.tensors[0]).copy()
        ch.push(stamp(src, (1, 1)))
        np.testing.assert_array_equal(np.asarray(src.tensors[0]), original)
        wire = ch.pop()
        assert checksum(wire) != int(wire.meta["crc"])   # damage is real

    def test_overflow_drops_stay_on_the_ledger(self):
        fabric = FaultFabric()
        ch = Channel(capacity=2)
        link = fabric.install(ch, FaultPolicy())
        for i in range(3):
            ch.push(_buf(i))
        assert link.overflow_drops == 1
        fabric.assert_conservation()      # sent 3 = in_flight 2 + overflow 1

    def test_guard_verdicts_book_back_onto_the_link(self):
        """End-to-end unit of the conservation law: a guarded receiver's
        verdicts (accepted / deduped / rejected_corrupt) land on the link
        that carried the frames, and the ledger balances exactly."""
        fabric = FaultFabric()
        ch = Channel(capacity=256)
        link = fabric.install(ch, FaultPolicy(seed=2, drop=0.1, dup=0.2,
                                              corrupt=0.1))
        guard = DeliveryGuard(DELIVERY)
        for i in range(100):
            ch.push(stamp(_buf(i), (1, i)))
        while True:
            raw = ch.pop()
            if raw is None:
                break
            guard.check(raw, ch)
        assert link.dropped_fault > 0 and link.injected_dups > 0 \
            and link.corrupted > 0
        assert guard.deduped > 0 and guard.rejected_corrupt > 0
        fabric.assert_conservation()


class TestDeliveryGuard:
    def test_dedup_by_delivery_id(self):
        g = DeliveryGuard(DELIVERY)
        raw = stamp(_buf(0), (7, 1))
        assert g.check(raw) == "ok"
        assert g.check(raw) == "dup"
        assert g.stats()["deduped"] == 1

    def test_corrupt_is_rejected_before_dedup(self):
        g = DeliveryGuard(DELIVERY)
        raw = stamp(_buf(5), (7, 1))
        bad = raw.with_(tensors=(np.zeros((4,), np.float32),))
        assert g.check(bad) == "corrupt"
        # the corrupt copy must NOT have burned the delivery id: the
        # sender's retransmit of the intact frame is the first delivery
        assert g.check(raw) == "ok"

    def test_undelivered_meta_passes_through(self):
        g = DeliveryGuard(DELIVERY)
        assert g.check(_buf(0)) == "ok"       # no dseq, no crc: old traffic
        assert g.check(_buf(0)) == "ok"       # and never deduped

    def test_window_is_bounded_lru(self):
        g = DeliveryGuard(DeliveryPolicy(window=3))
        for i in range(4):
            assert g.check(stamp(_buf(i), (1, i))) == "ok"
        assert not g.seen((1, 0))             # evicted, oldest first
        assert g.seen((1, 3))
        assert g.check(stamp(_buf(3), (1, 3))) == "dup"

    def test_forget_reopens_a_shed_id(self):
        """A request shed UNSERVED (endpoint death mid-queue) must leave
        the window, or the failover re-dispatch — same delivery id — would
        dedup into a void."""
        g = DeliveryGuard(DELIVERY)
        raw = stamp(_buf(0), (7, 1))
        assert g.check(raw) == "ok"
        fired = []
        g.record_answer((7, 1), lambda: fired.append(1))
        g.forget((7, 1))
        assert g.check(raw) == "ok"           # the retry is served fresh
        assert g.replay_answer((7, 1)) is False   # stale answer gone too
        assert not fired

    def test_replay_refires_the_committed_answer(self):
        g = DeliveryGuard(DELIVERY)
        fired = []
        g.record_answer((7, 1), lambda: fired.append(1))
        assert g.replay_answer((7, 1)) is True
        assert fired == [1]
        assert g.stats()["replayed"] == 1

    def test_backoff_schedule(self):
        pol = DeliveryPolicy(timeout_ticks=2, backoff=2.0,
                             max_backoff_ticks=16)
        sched = [pol.retry_in(k) for k in range(6)]
        assert sched == [2, 4, 8, 16, 16, 16]
        assert DeliveryPolicy(timeout_ticks=0).retry_in(0) == 1  # never 0


# -- chaos-pinned parity: plain queries ---------------------------------------

FAULT_CLASSES = {
    "drop": FaultPolicy(seed=11, drop=0.08),
    "dup": FaultPolicy(seed=12, dup=0.15),
    "reorder": FaultPolicy(seed=13, reorder=0.2),
    "corrupt": FaultPolicy(seed=14, corrupt=0.08),
    "delay": FaultPolicy(seed=15, delay=0.15, delay_ticks=(1, 2)),
}

MIXED = FaultPolicy(seed=21, drop=0.05, dup=0.05, corrupt=0.04,
                    reorder=0.08, delay=0.08, delay_ticks=(1, 2))

FIRED_COUNTER = {"drop": "dropped_by_fault", "dup": "injected_dups",
                 "reorder": "reordered", "corrupt": "corrupted",
                 "delay": "delayed"}


def _lossy_twin(ticks, n_clients, req_pol, ans_pol, query_batch=8):
    """Build the fault-free twin and the lossy run, same script."""
    rt0 = Runtime(query_batch=query_batch, delivery=DELIVERY)
    _server(rt0)
    ref = _clients(rt0, n_clients)
    rt0.run(ticks)

    rt = Runtime(query_batch=query_batch, delivery=DELIVERY)
    _, _, ssrc = _server(rt)
    got = _clients(rt, n_clients)
    fabric = FaultFabric()
    rt.fabric = fabric
    links = lossy_endpoint(fabric, ssrc.endpoint, req_pol, ans_pol,
                           name="hub")
    rt.run(ticks)
    return rt0, ref, rt, got, fabric, links


class TestPlainQueryParity:
    @pytest.mark.parametrize("fault", sorted(FAULT_CLASSES))
    def test_each_fault_class_bitwise(self, fault):
        """Both directions lossy (request link + every answer link), one
        fault class at a time so a regression names its fault."""
        pol = FAULT_CLASSES[fault]
        ticks, n_clients = 24, 4
        rt0, ref, rt, got, fabric, links = _lossy_twin(
            ticks, n_clients, pol, pol)
        fired = sum(link.stats()[FIRED_COUNTER[fault]] for link in links)
        assert fired > 0, f"the {fault} schedule never fired"
        # liveness floor, not cadence: one frame that loses three straight
        # attempts stalls its client ~14 ticks on the backoff clock
        _assert_prefix_bitwise(ref, got, min_answers=ticks // 3)
        fabric.assert_conservation()
        d = rt.stats()["delivery"]
        if fault == "corrupt":
            assert d["rejected_corrupt"] + d["client_answer_corrupt"] > 0
        if fault == "drop":
            assert d["retransmits"] > 0
            assert d["replayed"] + d["accepted"] > 0

    @pytest.mark.parametrize("query_batch", [1, 4, 8])
    def test_mixed_faults_across_batch_sizes(self, query_batch):
        """All five fault classes at once, at batch 1 / 4 / 8: the fused
        dispatch round and the legacy per-frame path both hold the
        effectively-once contract."""
        ticks, n_clients = 40, 4
        rt0, ref, rt, got, fabric, _ = _lossy_twin(
            ticks, n_clients, MIXED, MIXED, query_batch=query_batch)
        # a frame that loses its first three attempts waits out the 16-tick
        # backoff cap — the floor tolerates one such streak per client
        _assert_prefix_bitwise(ref, got, min_answers=ticks // 4)
        fabric.assert_conservation()

    def test_scripted_partition_heals_with_backoff(self):
        """A 4-tick full request-plane partition: every send in the window
        is eaten, the backoff clock carries the retransmits across the
        outage, and after the heal every client catches up — bitwise."""
        ticks, n_clients = 18, 3
        part = FaultPolicy(partitions=((4, 8),))
        rt0, ref, rt, got, fabric, links = _lossy_twin(
            ticks, n_clients, part, None)
        assert links[0].dropped_fault >= n_clients   # the window really bit
        assert rt.stats()["delivery"]["retransmits"] > 0
        _assert_prefix_bitwise(ref, got, min_answers=10)
        fabric.assert_conservation()

    def test_delivery_layer_is_inert_on_clean_links(self):
        """Sanity for the opt-in: with delivery ON but the transport clean,
        answers and cadence are bitwise the delivery-OFF runtime's, and
        nothing ever retransmits."""
        ticks, n_clients = 8, 3
        rt0 = Runtime(query_batch=8)
        _server(rt0)
        ref = _clients(rt0, n_clients)
        rt0.run(ticks)
        rt = Runtime(query_batch=8, delivery=DELIVERY)
        _server(rt)
        got = _clients(rt, n_clients)
        rt.run(ticks)
        for r, g in zip(ref, got):
            assert g.frames == ticks
            a, b = _responses(r), _responses(g)
            assert len(a) == len(b) == ticks
            for x, y in zip(a, b):
                np.testing.assert_array_equal(x, y)
        d = rt.stats()["delivery"]
        assert d["retransmits"] == 0 and d["deduped"] == 0 \
            and d["rejected_corrupt"] == 0


# -- suspicion vs declared death ----------------------------------------------

class TestSuspicionAndHeal:
    def test_control_partition_suspects_then_wins_back(self, chaos):
        """Heartbeats lost, device fine: the lease lapses into SUSPICION,
        clients fail over, and the heal (resumed beats) revives the
        registration through the broker's win-back — zero loss, bitwise."""
        ticks, n_clients = 14, 4
        rt0 = Runtime(query_batch=8, lease_ticks=2, delivery=DELIVERY)
        _server(rt0, name="hubA")
        _server(rt0, name="hubB")
        ref = _clients(rt0, n_clients)
        rt0.run(ticks)

        rt = Runtime(query_batch=8, lease_ticks=2, delivery=DELIVERY)
        devA, runA, ssrcA = _server(rt, name="hubA")
        devB, runB, ssrcB = _server(rt, name="hubB")
        got = _clients(rt, n_clients)
        harness = chaos(rt)
        harness.partition_control(4, 9, devA)
        harness.run(ticks)

        assert rt.broker.suspicions >= 1
        assert rt.broker.heals >= 1
        reg = ssrcA.registration
        assert reg.alive and not reg.suspected    # healed, back in service
        assert runB.frames > 0                    # the failover really served
        # zero loss, zero duplicates: one answer per tick per client,
        # bitwise the twin's — the win-back double-served nothing
        for r, g in zip(ref, got):
            assert g.frames == ticks
            a, b = _responses(r), _responses(g)
            assert len(a) == len(b) == ticks
            for x, y in zip(a, b):
                np.testing.assert_array_equal(x, y)

    def test_crash_is_declared_death_not_suspicion(self, chaos):
        """An announced mark_down must not look like a lease lapse: no
        suspicion is raised and heal() refuses to revive a crashed
        registration on its own."""
        rt = Runtime(query_batch=8, lease_ticks=4, delivery=DELIVERY)
        devA, _, ssrcA = _server(rt, name="hubA")
        _server(rt, name="hubB")
        _clients(rt, 2)
        harness = chaos(rt)
        harness.kill_server(3, devA, ssrcA, crash=True)
        harness.run(6)
        reg = ssrcA.registration
        assert not reg.alive and not reg.suspected
        assert rt.broker.suspicions == 0
        assert rt.broker.heal(reg) is False       # crash needs revive_server

    def test_silent_death_is_suspicion_until_revived(self, chaos):
        """The other half of the split: a SILENT death (no mark_down) does
        lapse into suspicion — the state the §3 lease expiry already
        detected now carries the suspected flag for the heal path."""
        rt = Runtime(query_batch=8, lease_ticks=2, delivery=DELIVERY)
        devA, _, ssrcA = _server(rt, name="hubA")
        _server(rt, name="hubB")
        cl = _clients(rt, 2)
        harness = chaos(rt)
        harness.kill_server(3, devA, ssrcA, crash=False)
        harness.run(10)
        reg = ssrcA.registration
        assert not reg.alive and reg.suspected
        assert reg.down_reason == "lease-expired"
        assert rt.broker.suspicions == 1
        assert all(r.frames == 10 for r in cl)    # failover bridged it all


# -- mid-generation streams (§7) ----------------------------------------------

class TestStreamingUnderLoss:
    def test_streaming_answers_bitwise_under_mixed_faults(self):
        """model_serve continuous batching with a lossy client link: a
        duplicated prompt must not double-admit a stream (slot burn), a
        corrupt one must be rejected-then-retransmitted, and every token
        stream delivered is bitwise the fault-free twin's."""
        ticks, n_clients = 16, 3
        pol = FaultPolicy(seed=31, drop=0.05, dup=0.12, corrupt=0.05)

        def build(lossy):
            rt = Runtime(query_batch=8, delivery=DELIVERY)
            dev = Device("hub")
            ps = ms.serve_pipeline(slots=8, max_seq=32)
            run = dev.add_pipeline(ps, jit=False)
            rt.add_device(dev)
            cls = [self._lm_client(rt, i) for i in range(n_clients)]
            fabric = None
            if lossy:
                fabric = FaultFabric()
                rt.fabric = fabric
                lossy_endpoint(fabric, ps.elements["ssrc"].endpoint,
                               pol, pol, name="lm")
            rt.run(ticks)
            return rt, cls, fabric

        rt0, ref, _ = build(lossy=False)
        rt, got, fabric = build(lossy=True)

        for r, g in zip(ref, got):
            a = [np.asarray(b.tensor).tolist() for b in
                 r.sink_log.get("res", [])]
            b = [np.asarray(x.tensor).tolist() for x in
                 g.sink_log.get("res", [])]
            assert len(b) >= 1                     # liveness under loss
            assert b == a[:len(b)]                 # bitwise token streams
        fabric.assert_conservation()
        (batcher,) = [b for b in rt._batchers.values()
                      if getattr(b, "tokens_generated", None) is not None]
        st = batcher.stats()
        # token conservation and no double-admitted streams: every stream
        # maps to one accepted prompt, duplicates all landed in the dedup
        assert st["tokens_generated"] == st["tokens_delivered"] + \
            st["tokens_dropped"] + st["tokens_in_flight"]
        d = rt.stats()["delivery"]
        assert st["streams_started"] <= d["accepted"]

    @staticmethod
    def _lm_client(rt, i):
        dev = Device(f"tv{i}")
        run = dev.add_pipeline(
            ms.client_pipeline(prompts=f"{i+1},{i+2},{i+3}", gens="4"),
            jit=False)
        rt.add_device(dev)
        return run


# -- mid-generation stage hops (§8) -------------------------------------------

class TestStagedHopsUnderLoss:
    def test_staged_decode_bitwise_with_lossy_hop_link(self):
        """The §8 chain with the stage-1 hop link lossy in BOTH directions:
        duplicated hops dedup + replay at the stage guard (a replayed
        decode hop must not double-advance a slot), corrupt hops are
        rejected and synchronously retransmitted, and the delivered token
        streams stay bitwise the fault-free twin's."""
        ticks, n_clients = 14, 2
        req_pol = FaultPolicy(seed=41, dup=0.12, corrupt=0.06, drop=0.03)
        ans_pol = FaultPolicy(seed=42, dup=0.10)

        def build(lossy):
            rt = Runtime(query_batch=8, delivery=DELIVERY)
            stages = []
            for k, ps in enumerate(ms.staged_serve_pipelines(
                    model="stablelm-smoke-4l", slots=8, max_seq=32,
                    n_stages=2)):
                dev = Device(f"stage{k}")
                dev.add_pipeline(ps, jit=False)
                rt.add_device(dev)
                stages.append(ps)
            cls = []
            for i in range(n_clients):
                dev = Device(f"tv{i}")
                cls.append(dev.add_pipeline(
                    ms.client_pipeline(prompts=f"{i+1},{i+2}", gens="4"),
                    jit=False))
                rt.add_device(dev)
            fabric = None
            if lossy:
                fabric = FaultFabric()
                rt.fabric = fabric
                lossy_endpoint(fabric, stages[1].elements["ssrc"].endpoint,
                               req_pol, ans_pol, name="s1")
            rt.run(ticks)
            return rt, cls, fabric

        rt0, ref, _ = build(lossy=False)
        rt, got, fabric = build(lossy=True)

        for r, g in zip(ref, got):
            a = [np.asarray(b.tensor).tolist() for b in
                 r.sink_log.get("res", [])]
            b = [np.asarray(x.tensor).tolist() for x in
                 g.sink_log.get("res", [])]
            assert len(b) >= 1
            assert b == a[:len(b)]                 # bitwise token streams
        fabric.assert_conservation()
        (coord,) = [b for b in rt._batchers.values()
                    if isinstance(b, StagedStreamingBatcher)]
        st = coord.stats()
        assert st["tokens_generated"] == st["tokens_delivered"] + \
            st["tokens_dropped"] + st["tokens_in_flight"]
        for k in range(1, coord.n_stages):
            led = coord.stage_ledger(k)
            assert led["dispatched"] == led["completed"] + led["failed"]
        # the fault schedule really exercised the hop delivery machinery
        assert st["hop_retransmits"] + st["hop_dups"] + st["hop_corrupt"] \
            + rt.stats()["delivery"]["deduped"] > 0


# -- the lossy soak -----------------------------------------------------------

@pytest.mark.soak
class TestLossySoak:
    def test_200_tick_lossy_soak_conserves_everything(self, chaos):
        """200 ticks of 5% drop / 2% dup / delay jitter on the plain-query
        fabric with mid-generation §7 streams live in the same runtime,
        plus one scripted 20-tick request-plane partition that heals.
        Exact conservation: per-link message ledgers, the §7 token law,
        and zero client-visible loss (every delivered answer bitwise the
        fault-free twin's, every client makes progress past the heal)."""
        ticks, n_plain, n_lm = 200, 4, 2
        lossy = FaultPolicy(seed=51, drop=0.05, dup=0.02, delay=0.05,
                            delay_ticks=(1, 3))
        lossy_part = dataclasses.replace(lossy, partitions=((80, 100),))

        def build(with_faults):
            rt = Runtime(query_batch=8, lease_ticks=4, delivery=DELIVERY)
            _, _, ssrc = _server(rt, name="hub")
            plain = _clients(rt, n_plain)
            lmdev = Device("lmhub")
            lmps = ms.serve_pipeline(slots=8, max_seq=32)
            lmdev.add_pipeline(lmps, jit=False)
            rt.add_device(lmdev)
            lm = [TestStreamingUnderLoss._lm_client(rt, i)
                  for i in range(n_lm)]
            fabric = None
            if with_faults:
                fabric = FaultFabric()
                rt.fabric = fabric
                lossy_endpoint(fabric, ssrc.endpoint, lossy_part, lossy,
                               name="hub")
                lossy_endpoint(fabric, lmps.elements["ssrc"].endpoint,
                               lossy, lossy, name="lm")
            rt.run(ticks)
            return rt, plain, lm, fabric

        rt0, ref_plain, ref_lm, _ = build(False)
        rt, plain, lm, fabric = build(True)

        # zero client-visible loss, bitwise, with liveness PAST the heal:
        # >=100 answers in 200 ticks means every client kept answering
        # well after the partition healed at tick 100
        _assert_prefix_bitwise(ref_plain, plain, min_answers=100)
        for r, g in zip(ref_lm, lm):
            a = [np.asarray(b.tensor).tolist() for b in
                 r.sink_log.get("res", [])]
            b = [np.asarray(x.tensor).tolist() for x in
                 g.sink_log.get("res", [])]
            assert len(b) >= len(a) // 2
            assert b == a[:len(b)]
        # exact message conservation on every link, partition included
        fabric.assert_conservation()
        # the schedule really was adversarial
        st = rt.stats()
        d = st["delivery"]
        assert d["retransmits"] > 0 and d["deduped"] > 0
        assert sum(link["dropped_by_fault"]
                   for link in st["netfault"].values()) > 0
        # §7 token law, exact
        (batcher,) = [b for b in rt._batchers.values()
                      if getattr(b, "tokens_generated", None) is not None]
        bs = batcher.stats()
        assert bs["tokens_generated"] == bs["tokens_delivered"] + \
            bs["tokens_dropped"] + bs["tokens_in_flight"]
