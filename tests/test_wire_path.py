"""Fused batched wire path (DESIGN.md §5): bitwise parity of every fast
path against its eager reference.

The PR-5 contract is that NOTHING on the wire path may move a bit:

* the XLA kernel fast paths equal the Pallas kernels (the TPU story and the
  CPU story encode the same block/tile contract);
* the stacked kernel entry points equal per-frame calls (tile/block merge);
* the batched host codec helpers equal per-frame ``encode``/``decode``
  including meta and the deferred truncation accounting totals;
* jitted deferred segments equal the interpreted deferred walk;
* a fused runtime's client responses equal the eager runtime's AND the
  sequential runtime's, at batch {1, 4, 8}, for quant8 and sparse clients.

Perf-marked smoke checks keep generous bounds — the real gates live in
``benchmarks/bench_wire_path.py``.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import StreamBuffer, TensorSpec, parse_launch
from repro.core import compression as comp
from repro.core.elements import register_model
from repro.kernels import ops as kops
from repro.runtime import Device, Runtime


@pytest.fixture(scope="module", autouse=True)
def models():
    def init(rng):
        return {"w": jax.random.normal(rng, (12, 4)) * 0.3}

    def apply(p, x):
        return x.astype(jnp.float32).reshape(1, -1) @ p["w"]

    register_model("wpsvc", init, apply,
                   out_specs=(TensorSpec((1, 4), "float32"),))


def _server(rt, name="hub"):
    dev = Device(name)
    ps = parse_launch(
        "tensor_query_serversrc operation=op name=ssrc ! "
        "tensor_filter model=wpsvc ! tensor_query_serversink name=ssink")
    ps.elements["ssink"].pair_with(ps.elements["ssrc"])
    run = dev.add_pipeline(ps, jit=False)
    rt.add_device(dev)
    return run


def _clients(rt, n, codec="quant8"):
    runs = []
    for i in range(n):
        dev = Device(f"tv{i}")
        pc = parse_launch(
            f"testsrc width=2 height=2 ! tensor_converter ! "
            f"tensor_query_client operation=op codec={codec} name=qc ! "
            f"appsink name=res")
        runs.append(dev.add_pipeline(pc, jit=False))
        rt.add_device(dev)
    return runs


def _responses(run):
    return [np.asarray(b.tensor) for b in run.sink_log["res"]]


def _leaves_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# kernel layer
# ---------------------------------------------------------------------------

class TestKernelImplParity:
    """The XLA fast paths ARE the kernels, bit for bit."""

    @pytest.mark.parametrize("shape", [(13, 7), (129,), (3, 5, 2), (),
                                       (64, 256)])
    def test_quant8_xla_equals_pallas(self, shape):
        x = jax.random.normal(jax.random.PRNGKey(0), shape)
        qp, sp = kops.quantize8(x, impl="pallas")
        qx, sx = kops.quantize8(x, impl="xla")
        np.testing.assert_array_equal(np.asarray(qp), np.asarray(qx))
        np.testing.assert_array_equal(np.asarray(sp), np.asarray(sx))
        np.testing.assert_array_equal(
            np.asarray(kops.dequantize8(qp, sp, impl="pallas")),
            np.asarray(kops.dequantize8(qp, sp, impl="xla")))

    @pytest.mark.parametrize("n,cap", [(7, 3), (200, 20), (600, 600),
                                       (1024, 256), (5000, 1000)])
    def test_sparse_xla_equals_pallas(self, n, cap):
        x = jax.random.normal(jax.random.PRNGKey(1), (n,))
        x = jnp.where(jax.random.uniform(jax.random.PRNGKey(2), (n,)) < 0.3,
                      x, 0.0)
        vp, ip, np_ = kops.sparse_enc(x, cap, impl="pallas")
        vx, ix, nx = kops.sparse_enc(x, cap, impl="xla")
        np.testing.assert_array_equal(np.asarray(vp), np.asarray(vx))
        np.testing.assert_array_equal(np.asarray(ip), np.asarray(ix))
        assert int(np_) == int(nx)
        np.testing.assert_array_equal(
            np.asarray(kops.sparse_dec(vp, ip, np_, n, impl="pallas")),
            np.asarray(kops.sparse_dec(vp, ip, np_, n, impl="xla")))

    def test_auto_dispatch_picks_xla_off_tpu(self):
        assert kops.use_interpret()          # CI boxes have no TPU
        assert kops._impl(None) == "xla"
        with pytest.raises(ValueError, match="impl"):
            kops._impl("fast")


class TestStackedKernelParity:
    """Stacked entry points == per-frame calls (tile/block merge)."""

    @pytest.mark.parametrize("impl", ["xla", "pallas"])
    @pytest.mark.parametrize("shape", [(13, 7), (40,), (3, 5, 2)])
    def test_quant8_stacked(self, impl, shape):
        xs = jax.random.normal(jax.random.PRNGKey(3), (5,) + shape)
        qs, ss = kops.quantize8_stacked(xs, impl=impl)
        xr = kops.dequantize8_stacked(qs, ss, impl=impl)
        for i in range(5):
            q1, s1 = kops.quantize8(xs[i], impl=impl)
            np.testing.assert_array_equal(np.asarray(qs[i]), np.asarray(q1))
            np.testing.assert_array_equal(np.asarray(ss[i]), np.asarray(s1))
            np.testing.assert_array_equal(
                np.asarray(xr[i]),
                np.asarray(kops.dequantize8(q1, s1, impl=impl)))

    @pytest.mark.parametrize("impl", ["xla", "pallas"])
    @pytest.mark.parametrize("n,cap", [(200, 20), (1024, 256), (600, 600)])
    def test_sparse_stacked(self, impl, n, cap):
        xs = jax.random.normal(jax.random.PRNGKey(4), (4, n))
        xs = jnp.where(
            jax.random.uniform(jax.random.PRNGKey(5), (4, n)) < 0.3, xs, 0.0)
        vs, is_, nz = kops.sparse_enc_stacked(xs, cap, impl=impl)
        ds = kops.sparse_dec_stacked(vs, is_, nz, n, impl=impl)
        for i in range(4):
            v1, i1, n1 = kops.sparse_enc(xs[i], cap, impl=impl)
            np.testing.assert_array_equal(np.asarray(vs[i]), np.asarray(v1))
            np.testing.assert_array_equal(np.asarray(is_[i]), np.asarray(i1))
            assert int(nz[i]) == int(n1)
            np.testing.assert_array_equal(
                np.asarray(ds[i]),
                np.asarray(kops.sparse_dec(v1, i1, n1, n, impl=impl)))


# ---------------------------------------------------------------------------
# codec layer
# ---------------------------------------------------------------------------

class TestBatchCodecParity:
    """encode_batch/decode_batch == per-frame encode/decode, including
    meta (codec claim, sparse_dropped) and the deferred accounting totals."""

    @pytest.mark.parametrize("codec", ["quant8", "sparse:0.25",
                                       "sparse:0.05", "none"])
    @pytest.mark.parametrize("batch", [1, 4, 8])
    def test_encode_batch_bitwise(self, codec, batch):
        bufs = [StreamBuffer(
            tensors=(jax.random.normal(jax.random.PRNGKey(i), (13, 7)),),
            pts=jnp.int32(i), meta={"client_id": i}) for i in range(batch)]
        comp.reset_codec_stats()
        eager = [comp.encode(b, codec) for b in bufs]
        stats_eager = comp.codec_stats()
        comp.reset_codec_stats()
        batched = comp.encode_batch(bufs, codec)
        assert comp.codec_stats() == stats_eager   # deferred totals agree
        for (eb, en), (bb, bn) in zip(eager, batched):
            assert en == bn
            assert eb.meta == bb.meta              # incl. sparse_dropped
            _leaves_equal(eb.tensors, bb.tensors)

    @pytest.mark.parametrize("codec", ["quant8", "sparse:0.25", "none"])
    def test_decode_batch_bitwise(self, codec):
        bufs = [StreamBuffer(
            tensors=(jax.random.normal(jax.random.PRNGKey(i), (13, 7)),),
            pts=jnp.int32(i), meta={"client_id": i}) for i in range(4)]
        wire = [comp.encode(b, codec)[0] for b in bufs]
        eager = [comp.decode(w, codec) for w in wire]
        batched = comp.decode_batch(wire, codec)
        for e, b in zip(eager, batched):
            assert e.meta == b.meta                # wire meta stripped alike
            _leaves_equal(e.tensors, b.tensors)

    def test_truncation_accounting_defers_to_one_sync(self):
        """The dropped counts cross the host boundary once per batch call,
        and the per-frame meta signal survives the deferral."""
        dense = jnp.asarray(np.arange(1, 201, dtype=np.float32))
        bufs = [StreamBuffer(tensors=(dense * (i + 1),), pts=jnp.int32(i))
                for i in range(4)]
        comp.reset_codec_stats()
        batched = comp.encode_batch(bufs, "sparse:0.05")
        stats = comp.codec_stats()
        assert stats["sparse_truncated_tensors"] == 4
        per_frame = [b.meta["sparse_dropped"] for b, _ in batched]
        assert all(d > 0 for d in per_frame)
        assert sum(per_frame) == stats["sparse_dropped_values"]


# ---------------------------------------------------------------------------
# plan layer
# ---------------------------------------------------------------------------

class TestDeferredSegments:
    def test_compiled_deferral_matches_interpreted_bitwise(self):
        pc = parse_launch(
            "testsrc width=2 height=2 ! tensor_converter ! "
            "tensor_query_client operation=x name=qc ! appsink name=o"
        ).realize()
        params, s0 = pc.init(jax.random.PRNGKey(0)), pc.init_state()
        assert pc.plan.deferred_compilable
        pq_i = pc.plan.run_deferred(params, s0)
        pq_c = pc.plan.run_deferred_compiled(params, s0)
        assert pq_c.is_compiled and pq_c.client is pq_i.client
        _leaves_equal(pq_i.request, pq_c.request)
        answer = pq_i.request.with_(tensors=(jnp.ones((1, 4)),))
        out_i, st_i = pq_i.resume(answer)
        out_c, st_c = pq_c.resume(answer)
        _leaves_equal(out_i["o"], out_c["o"])
        _leaves_equal(st_i, st_c)

    def test_segments_cached_by_fingerprint(self):
        pc = parse_launch(
            "testsrc width=2 height=2 ! tensor_converter ! "
            "tensor_query_client operation=x name=qc ! appsink name=o"
        ).realize()
        params, s0 = pc.init(jax.random.PRNGKey(0)), pc.init_state()
        pc.plan.run_deferred_compiled(params, s0)
        fns = pc.plan._cache()["fns"]
        assert ("defer_seg", -1) in fns
        n = len(fns)
        pq = pc.plan.run_deferred_compiled(params, s0)
        pq.resume(pq.request.with_(tensors=(jnp.ones((1, 4)),)))
        assert ("defer_seg", pq.op_idx) in fns or \
            any(k[0] == "defer_seg" for k in fns)
        pc.plan.run_deferred_compiled(params, s0)
        assert len(fns) == len(pc.plan._cache()["fns"])

    def test_impure_prefix_is_not_compilable(self):
        pc = parse_launch(
            "mqttsrc sub-topic=cam name=src ! tensor_converter ! "
            "tensor_query_client operation=x name=qc ! appsink name=o"
        ).realize()
        assert pc.plan.has_query_clients
        assert not pc.plan.deferred_compilable


# ---------------------------------------------------------------------------
# runtime level (the acceptance surface)
# ---------------------------------------------------------------------------

class TestFusedRuntimeParity:
    @pytest.mark.parametrize("codec", ["quant8", "sparse:0.25"])
    @pytest.mark.parametrize("batch", [1, 4, 8])
    def test_fused_matches_eager_and_sequential_bitwise(self, codec, batch):
        """THE acceptance pin: fused batched responses == eager batched ==
        sequential, bitwise, for codec clients at batch {1,4,8} — and the
        fused path really served (no silent fallback)."""
        ticks, n_clients = 2, 4
        streams = {}
        for label, kw in (
                ("fused", dict(query_batch=batch)),
                ("eager", dict(query_batch=batch, fused_wire=False)),
                ("sequential", dict(query_batch=0))):
            comp.reset_codec_stats()
            rt = Runtime(**kw)
            _server(rt)
            runs = _clients(rt, n_clients, codec=codec)
            rt.run(ticks)
            streams[label] = [_responses(r) for r in runs]
            if label == "fused":
                qb = rt.stats()["query_batching"]
                assert qb["fused_frames"] == ticks * n_clients
            stats = comp.codec_stats()
            if label == "fused":
                fused_stats = stats
            elif label == "eager":
                # deferred truncation accounting sums to the eager totals
                assert stats == fused_stats
        for label in ("eager", "sequential"):
            for ref, got in zip(streams["fused"], streams[label]):
                assert len(ref) == len(got) == ticks
                for a, b in zip(ref, got):
                    np.testing.assert_array_equal(a, b)

    @pytest.mark.parametrize("codec", ["quant8", "sparse:0.25"])
    def test_decoded_answers_never_claim_a_codec(self, codec):
        """meta["codec"]-strip contract through the whole fused round trip:
        what lands in the client's appsink is a DECODED frame."""
        rt = Runtime(query_batch=4)
        _server(rt)
        runs = _clients(rt, 4, codec=codec)
        rt.run(2)
        for r in runs:
            for buf in r.sink_log["res"]:
                assert "codec" not in buf.meta
                assert "sparse_dropped" not in buf.meta

    def test_wire_buffers_on_the_channel_do_claim_their_codec(self):
        """...while the frames actually in flight are stamped wire-form."""
        rt = Runtime(query_batch=8)
        srv = _server(rt)
        _clients(rt, 2, codec="quant8")
        ssrc = srv.pipe.elements["ssrc"]
        seen = []
        orig_push = ssrc.endpoint.requests.push

        def spy(buf, nbytes=None):
            seen.append(buf)
            return orig_push(buf, nbytes)
        ssrc.endpoint.requests.push = spy
        rt.run(1)
        assert seen
        for buf in seen:
            assert buf.meta["codec"] == "quant8"
            from repro.core.buffers import Quant8Payload
            assert all(isinstance(t, Quant8Payload) for t in buf.tensors)


# ---------------------------------------------------------------------------
# perf smoke (generous bounds; real gates in benchmarks/bench_wire_path.py)
# ---------------------------------------------------------------------------

@pytest.mark.perf
class TestPerfSmoke:
    def test_sparse_enc_lm_frame_under_pr4_floor(self):
        """PR-4 measured ~101.8 ms for this exact encode; the fast path
        must land far under it even on a noisy CI box (bound 10x slack
        over the ~2.7 ms measured)."""
        x = jax.random.normal(jax.random.PRNGKey(0), (64 * 1024,))
        cap = int(x.size * 0.25)
        jax.block_until_ready(kops.sparse_enc(x, cap))  # compile
        best = min(_timed(lambda: jax.block_until_ready(
            kops.sparse_enc(x, cap))) for _ in range(3))
        assert best < 0.030, f"sparse_enc took {best * 1e3:.1f} ms"
        # and it is still the kernel, bit for bit
        v, i, n = kops.sparse_enc(x, cap)
        vp, ip, np_ = kops.sparse_enc(x, cap, impl="pallas")
        np.testing.assert_array_equal(np.asarray(v), np.asarray(vp))

    def test_encode_batch_amortizes_dispatch(self, monkeypatch):
        """The amortization property itself, deterministically: a batch of
        8 frames hits the stacked kernel ONCE where the per-frame loop pays
        8 kernel dispatches (wall-clock comparison at this size is noise —
        the timed gate lives in benchmarks/bench_wire_path.py)."""
        calls = {"single": 0, "stacked": 0}
        real_single, real_stacked = kops.quantize8, kops.quantize8_stacked

        def spy_single(*a, **k):
            calls["single"] += 1
            return real_single(*a, **k)

        def spy_stacked(*a, **k):
            calls["stacked"] += 1
            return real_stacked(*a, **k)
        monkeypatch.setattr(kops, "quantize8", spy_single)
        monkeypatch.setattr(kops, "quantize8_stacked", spy_stacked)
        frames = [StreamBuffer(
            tensors=(jax.random.normal(jax.random.PRNGKey(i), (192,)),),
            pts=jnp.int32(i)) for i in range(8)]
        [comp.encode(f, "quant8") for f in frames]
        assert calls == {"single": 8, "stacked": 0}
        calls.update(single=0)
        comp.encode_batch(frames, "quant8")
        assert calls == {"single": 0, "stacked": 1}


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0
