"""Pallas quant8 kernel vs pure-jnp oracle: shape/dtype sweeps (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


def _check(x):
    q, s = ops.quantize8(x)
    qr, sr = ref.quantize8_ref(x.reshape(1, -1) if x.ndim == 1 else x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    xd = ops.dequantize8(q, s)
    x2 = np.asarray(x, np.float32).reshape(q.shape[0] and (-1, x.shape[-1]) or x.shape)
    m, n = (1, x.shape[0]) if x.ndim == 1 else x.shape
    err = np.abs(np.asarray(xd)[:m, :n] - np.asarray(x, np.float32).reshape(m, n))
    tol = np.abs(np.asarray(x)).max(initial=0) / 127 + 1e-7
    assert err.max(initial=0) <= tol + 1e-6


@given(st.integers(1, 70), st.integers(1, 300),
       st.sampled_from(["float32", "bfloat16"]),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_quant_roundtrip_sweep(m, n, dtype, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, n),
                          dtype=jnp.dtype(dtype)) * 5
    _check(x.astype(jnp.float32))


def test_zero_tile_scale_is_one():
    q, s = ops.quantize8(jnp.zeros((32, 128)))
    assert np.all(np.asarray(s) == 1.0)
    assert np.all(np.asarray(q) == 0)


def test_quant_error_bound_random():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 256)) * 3
    q, s = ops.quantize8(x)
    xd = ops.dequantize8(q, s)[:64, :256]
    # per-tile absmax/127 bound
    assert float(jnp.max(jnp.abs(xd - x))) <= float(jnp.max(jnp.abs(x))) / 127 + 1e-6


def test_3d_input_flattens():
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 96))
    q, s = ops.quantize8(x)
    assert q.shape[1] % 128 == 0
