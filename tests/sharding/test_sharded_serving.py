"""Mesh-sharded compiled serving (DESIGN.md §4).

Contract pinned here (and gated in benchmarks/bench_sharded_serving.py):
laying a serving batch out along a mesh's data axes may only change WHERE
frames execute, never what any client sees —

* responses under ``Runtime(mesh=...)`` are bitwise identical to
  single-device serving at batch {1, 4, 8};
* stateful server plans keep the FIFO single-device scan (state threads in
  arrival order — sharding such a plan would change frame ``i``'s inputs);
* the chaos acceptance scenario survives unchanged: a serving device dying
  mid-batch under the sharded path loses zero requests, answers bitwise;
* the executable cache is mesh-aware: same mesh never retraces (failover
  reconnects stay trace-free), different meshes never share executables.

The conftest in this directory forges 8 host devices before jax
initializes, so tier-1 exercises the real 8-way data axis on CPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TensorSpec, parse_launch
from repro.core.element import Element, register_element
from repro.core.elements import register_model
from repro.launch.mesh import data_axis_size, make_host_mesh, mesh_fingerprint
from repro.runtime import Device, Runtime

pytestmark = pytest.mark.multidevice


@pytest.fixture(scope="module", autouse=True)
def models():
    def init(rng):
        return {"w": jax.random.normal(rng, (12, 4)) * 0.3}

    def apply(p, x):
        return x.astype(jnp.float32).reshape(1, -1) @ p["w"]

    register_model("shsvc", init, apply,
                   out_specs=(TensorSpec((1, 4), "float32"),))


@register_element("running_sum4")
class RunningSum4(Element):
    """Stateful test element: accumulates the first 4 features across every
    frame it ever sees — serving order is observable in every answer, so a
    batch layout that broke FIFO threading could not pass bitwise."""

    def init_state(self):
        return {"acc": jnp.zeros((1, 4), jnp.float32)}

    def negotiate(self, in_caps):
        from repro.core.formats import Caps
        return [Caps(media="other/tensors",
                     tensors=(TensorSpec((1, 4), "float32"),))]

    def apply(self, params, inputs, ctx=None):
        buf = inputs[0]
        x = buf.tensors[0].astype(jnp.float32).reshape(1, -1)[:, :4]
        acc = ctx.get_state(self.name)["acc"] + x
        ctx.set_state(self.name, {"acc": acc})
        return [buf.with_(tensors=(acc,))]


def _server(rt, name="hub", operation="op", model="shsvc", filt=None, **specs):
    dev = Device(name)
    extra = " ".join(f"{k}={v}" for k, v in specs.items())
    mid = filt or f"tensor_filter model={model}"
    ps = parse_launch(
        f"tensor_query_serversrc operation={operation} name=ssrc {extra} ! "
        f"{mid} ! tensor_query_serversink name=ssink")
    ps.elements["ssink"].pair_with(ps.elements["ssrc"])
    run = dev.add_pipeline(ps, jit=False)
    rt.add_device(dev)
    return dev, run, ps.elements["ssrc"]


def _clients(rt, n, operation="op", codec="none"):
    runs = []
    for i in range(n):
        dev = Device(f"tv{i}")
        pc = parse_launch(
            f"testsrc width=2 height=2 ! tensor_converter ! "
            f"tensor_query_client operation={operation} codec={codec} "
            f"name=qc ! appsink name=res")
        runs.append(dev.add_pipeline(pc, jit=False))
        rt.add_device(dev)
    return runs


def _responses(run):
    return [np.asarray(b.tensor) for b in run.sink_log["res"]]


class TestBitwiseParity:
    @pytest.mark.parametrize("batch", [1, 4, 8])
    def test_sharded_matches_single_device_bitwise(self, batch):
        """Acceptance: mesh-sharded responses at batch {1,4,8} == the
        single-device runtime's responses, bitwise, for every client.  Only
        the 8-tiling batches actually shard (the rest fall back inside the
        same jitted call) — either way the numbers must not move."""
        ticks, n_clients = 3, 8
        rt_ref = Runtime(query_batch=batch)
        _server(rt_ref)
        ref_runs = _clients(rt_ref, n_clients)
        rt_ref.run(ticks)

        rt_m = Runtime(query_batch=batch, mesh=make_host_mesh(),
                       shard_mode="always")
        _, srv_run, _ = _server(rt_m)
        m_runs = _clients(rt_m, n_clients)
        rt_m.run(ticks)

        for rr, mr in zip(ref_runs, m_runs):
            assert rr.frames == ticks and mr.frames == ticks
            for a, b in zip(_responses(rr), _responses(mr)):
                np.testing.assert_array_equal(a, b)
        assert srv_run.frames == ticks * n_clients

    def test_uniform_codec_groups_still_shard_bitwise(self):
        """PR-5 composition rule: codec fusion is single-device, so a codec
        group the mesh may take keeps the PR-4 eager wire path (host decode
        → placement → sharded serve → host encode).  A full batch of
        same-codec clients therefore still shards — and stays bitwise with
        the meshless runtime."""
        def build(mesh):
            rt = Runtime(query_batch=8, mesh=mesh, shard_mode="always")
            _server(rt)
            runs = _clients(rt, 8, codec="quant8")
            rt.run(2)
            return rt, runs

        rt_m, m_runs = build(make_host_mesh())
        _, ref_runs = build(None)
        assert rt_m.stats()["query_batching"]["sharded_frames"] == 16
        for mr, rr in zip(m_runs, ref_runs):
            for a, b in zip(_responses(mr), _responses(rr)):
                np.testing.assert_array_equal(a, b)

    def test_mixed_codecs_on_a_mesh_split_by_codec_and_stay_bitwise(self):
        """PR-5 contract change: mixed-codec ticks split into consecutive
        same-codec groups (the codec is the fused executable's static trace
        parameter).  On this 8-way mesh the groups of 4 no longer tile the
        data axes, so they serve codec-fused on a single device — and the
        numbers still must not move vs the meshless runtime."""
        def build(mesh):
            rt = Runtime(query_batch=8, mesh=mesh, shard_mode="always")
            _server(rt)
            runs = _clients(rt, 4, codec="none") + \
                _clients(rt, 4, codec="quant8")
            rt.run(2)
            return rt, runs

        rt_m, m_runs = build(make_host_mesh())
        _, ref_runs = build(None)
        qb = rt_m.stats()["query_batching"]
        assert qb["sharded_frames"] == 0 and qb["fused_frames"] == 8
        for mr, rr in zip(m_runs, ref_runs):
            for a, b in zip(_responses(mr), _responses(rr)):
                np.testing.assert_array_equal(a, b)

    def test_eager_wire_path_keeps_mixed_codec_sharding(self):
        """The PR-4 behavior survives verbatim under fused_wire=False:
        codec is routing meta there, mixed codecs stack into one sharded
        batch, answers bitwise vs the meshless eager runtime."""
        def build(mesh):
            rt = Runtime(query_batch=8, mesh=mesh, shard_mode="always",
                         fused_wire=False)
            _server(rt)
            runs = _clients(rt, 4, codec="none") + \
                _clients(rt, 4, codec="quant8")
            rt.run(2)
            return rt, runs

        rt_m, m_runs = build(make_host_mesh())
        _, ref_runs = build(None)
        assert rt_m.stats()["query_batching"]["sharded_frames"] == 16
        for mr, rr in zip(m_runs, ref_runs):
            for a, b in zip(_responses(mr), _responses(rr)):
                np.testing.assert_array_equal(a, b)


class TestShardingMechanics:
    def test_sharded_path_used_at_batch_8(self):
        mesh = make_host_mesh()
        assert data_axis_size(mesh) >= 2
        rt = Runtime(query_batch=8, mesh=mesh, shard_mode="always")
        _, srv_run, _ = _server(rt)
        _clients(rt, 8)
        rt.run(3)
        qb = rt.stats()["query_batching"]
        assert qb["batched_frames"] == 24
        assert qb["sequential_frames"] == 0
        # every full batch tiled the data axis: all three flushes sharded
        assert qb["sharded_batches"] == 3
        assert qb["sharded_frames"] == 24
        assert srv_run.frames == 24

    def test_non_tiling_batch_falls_back_single_device(self):
        """5 requests cannot tile an 8-way data axis: the group serves on
        the single-device scan inside the same compiled call — served fully,
        just not sharded."""
        rt = Runtime(query_batch=8, mesh=make_host_mesh(),
                     shard_mode="always")
        _, srv_run, _ = _server(rt)
        runs = _clients(rt, 5)
        rt.run(2)
        qb = rt.stats()["query_batching"]
        assert qb["batched_frames"] == 10
        assert qb["sharded_frames"] == 0
        assert srv_run.frames == 10
        assert all(r.frames == 2 for r in runs)

    def test_stateful_server_keeps_fifo_scan(self):
        """A server plan threading cross-frame state must never shard — the
        running sum makes arrival order observable in every answer, so this
        doubles as a FIFO-threading bitwise check under the mesh runtime."""
        def build(mesh):
            rt = Runtime(query_batch=8, mesh=mesh, shard_mode="always")
            _, srv_run, ssrc = _server(rt, filt="running_sum4 name=acc")
            runs = _clients(rt, 8)
            rt.run(3)
            return rt, srv_run, runs

        rt_m, srv_m, m_runs = build(make_host_mesh())
        _, _, ref_runs = build(None)
        qb = rt_m.stats()["query_batching"]
        assert qb["sharded_frames"] == 0          # stateful: refused
        assert qb["batched_frames"] == 24         # ... but still batched
        for mr, rr in zip(m_runs, ref_runs):
            for a, b in zip(_responses(mr), _responses(rr)):
                np.testing.assert_array_equal(a, b)
        # the accumulator really threaded: answers grow tick over tick
        last = _responses(m_runs[-1])
        assert np.all(np.abs(last[-1]) >= np.abs(last[0]))

    def test_runtime_mesh_auto_builds_host_mesh(self):
        rt = Runtime(query_batch=8, mesh="auto")
        assert rt.mesh is not None
        assert data_axis_size(rt.mesh) == len(jax.devices())


class TestPlacementPolicy:
    """shard_mode: placement is a cost decision (core/batching.py) — auto
    probes both executables per batch size and keeps the faster; either
    pick is bitwise-correct, so policy may only move latency, never data."""

    def test_auto_mode_calibrates_once_and_stays_correct(self):
        rt = Runtime(query_batch=8, mesh=make_host_mesh())  # default auto
        _, srv_run, ssrc = _server(rt)
        runs = _clients(rt, 8)
        rt.run(3)
        batcher = rt._batchers[ssrc.endpoint.endpoint_id]
        assert batcher.placements.get(8) in ("sharded", "single")
        assert srv_run.frames == 24                # every request answered
        assert all(r.frames == 3 for r in runs)
        # the decision is sticky: stats are consistent with it
        qb = rt.stats()["query_batching"]
        if batcher.placements[8] == "sharded":
            assert qb["sharded_frames"] == 24
        else:
            assert qb["sharded_frames"] == 0
        assert qb["batched_frames"] == 24          # batched either way

    def test_auto_matches_forced_modes_bitwise(self):
        """Whatever auto picks, the answers equal both forced modes'."""
        streams = {}
        for mode in ("auto", "always", "never"):
            rt = Runtime(query_batch=8, mesh=make_host_mesh(),
                         shard_mode=mode)
            _server(rt)
            runs = _clients(rt, 8)
            rt.run(2)
            streams[mode] = [_responses(r) for r in runs]
        for mode in ("always", "never"):
            for ref, got in zip(streams["auto"], streams[mode]):
                for a, b in zip(ref, got):
                    np.testing.assert_array_equal(a, b)

    def test_auto_single_placement_reclaims_codec_fusion(self):
        """Regression (PR-5 review): a mesh runtime in auto mode used to
        route every mesh-tiling codec group down the eager wire path even
        after the probe had picked "single" — forfeiting codec fusion for
        nothing.  Only the probe-carrying flushes may serve eager; once the
        calibrated placement says "single", groups of that size must serve
        codec-FUSED."""
        rt = Runtime(query_batch=8, mesh=make_host_mesh(),
                     shard_mode="auto")
        _, srv_run, ssrc = _server(rt)
        _clients(rt, 8, codec="quant8")
        rt.run(3)
        batcher = rt._batchers[ssrc.endpoint.endpoint_id]
        qb = rt.stats()["query_batching"]
        if batcher.placements.get(8) == "single":
            # on this host-forged mesh the probe picks "single" (PR-4
            # documented outcome): ticks after the probe must be fused
            assert qb["fused_frames"] >= 16
        else:   # a real mesh where sharding wins keeps the eager path
            assert qb["sharded_frames"] > 0
        assert srv_run.frames == 24

    def test_never_mode_stays_single_device(self):
        rt = Runtime(query_batch=8, mesh=make_host_mesh(),
                     shard_mode="never")
        _, srv_run, ssrc = _server(rt)
        _clients(rt, 8)
        rt.run(2)
        assert rt.stats()["query_batching"]["sharded_frames"] == 0
        assert srv_run.frames == 16
        assert rt._batchers[ssrc.endpoint.endpoint_id].placements == {}

    def test_bad_mode_rejected(self):
        from repro.core.batching import BatchingPolicy, QueryBatcher
        with pytest.raises(ValueError, match="shard_mode"):
            QueryBatcher(None, None, BatchingPolicy(), shard_mode="bogus")
        # the Runtime validates too: a pub/sub-only deployment never builds
        # a batcher, and the burst path's string compare would otherwise
        # turn a typo into a silent "never"
        with pytest.raises(ValueError, match="shard_mode"):
            Runtime(mesh=make_host_mesh(), shard_mode="Always")

    def test_shardable_batch_predicate(self):
        mesh = make_host_mesh()
        d = data_axis_size(mesh)
        ps = parse_launch(
            "tensor_query_serversrc operation=x name=ssrc ! "
            "tensor_filter model=shsvc ! tensor_query_serversink name=ssink")
        ps.elements["ssink"].pair_with(ps.elements["ssrc"])
        ps.realize()
        plan = ps.plan
        assert plan.shardable_batch(d, {}, mesh)
        assert plan.shardable_batch(2 * d, {}, mesh)
        assert not plan.shardable_batch(d + 1, {}, mesh)
        assert not plan.shardable_batch(d, {}, None)
        assert not plan.shardable_batch(0, {}, mesh)
        # any state leaf forces the FIFO scan
        assert not plan.shardable_batch(
            d, {"acc": {"v": jnp.zeros((1,))}}, mesh)


class TestExecCacheMeshAware:
    def test_same_mesh_never_retraces_different_mesh_never_shares(self):
        mesh = make_host_mesh()
        rt = Runtime(query_batch=8, mesh=mesh, shard_mode="always")
        _, srv_run, _ = _server(rt)
        _clients(rt, 8)
        rt.run(1)
        fns = srv_run.pipe.plan._cache()["fns"]
        n_after_first = len(fns)
        # mesh-keyed entry exists and is distinct from the no-mesh key space
        # (serve_batch keys: (tag, donate, mesh fingerprint, codec))
        assert any(k[0] == "serve_batch" and k[2] == mesh_fingerprint(mesh)
                   for k in fns)
        rt.run(3)
        assert len(fns) == n_after_first      # same mesh: no new executables
        # an equivalent mesh object (same devices/layout) hits the same key
        mesh2 = make_host_mesh()
        assert mesh_fingerprint(mesh2) == mesh_fingerprint(mesh)
        srv_run.pipe.plan.compiled_serve_batch(mesh=mesh2)
        assert len(fns) == n_after_first
        # the single-device executable is a distinct entry (the mesh wrapper
        # created it eagerly as its non-tiling fallback) — requesting it
        # directly resolves to the cached one, no collision, no retrace
        assert ("serve_batch", False, None, None) in fns
        srv_run.pipe.plan.compiled_serve_batch(mesh=None)
        assert len(fns) == n_after_first
        # codec-fused executables never collide with the plain ones: the
        # codec fingerprint is part of the key
        srv_run.pipe.plan.compiled_serve_batch(codec="quant8")
        assert ("serve_batch", False, None, "quant8") in fns
        assert len(fns) == n_after_first + 1

    def test_failover_rewire_reuses_sharded_executable(self, chaos):
        """Kill + revive the serving device under the mesh runtime: the
        revived topology keeps its fingerprint AND its mesh, so nothing
        retraces across the outage."""
        mesh = make_host_mesh()
        rt = Runtime(query_batch=8, mesh=mesh, shard_mode="always")
        dev, srv_run, ssrc = _server(rt)
        cl = _clients(rt, 8)
        harness = chaos(rt)
        harness.kill_server(3, dev, ssrc)
        harness.revive_server(5, dev, ssrc)
        harness.run(2)
        fns = srv_run.pipe.plan._cache()["fns"]
        n_mid = len(fns)
        harness.run(5)
        assert len(fns) == n_mid
        assert all(r.frames >= 5 for r in cl)


class TestChaosUnderSharding:
    def test_mid_batch_server_death_sharded_loses_nothing_bitwise(self, chaos):
        """THE §3 acceptance scenario re-run on the sharded path: the
        primary dies while this tick's batch is mid-gather; orphans
        re-dispatch to the survivor (also mesh-sharded) within the tick —
        zero requests lost, answers bitwise vs the fault-free mesh twin."""
        ticks, n_clients, kill_tick = 6, 8, 3
        mesh = make_host_mesh()

        rt0 = Runtime(query_batch=8, mesh=mesh, shard_mode="always")
        _server(rt0, name="hubA")
        _server(rt0, name="hubB")
        ref_runs = _clients(rt0, n_clients)
        rt0.run(ticks)

        rt = Runtime(query_batch=8, mesh=mesh, shard_mode="always")
        devA, runA, ssrcA = _server(rt, name="hubA")
        devB, runB, ssrcB = _server(rt, name="hubB")
        cl_runs = _clients(rt, n_clients)
        harness = chaos(rt)
        harness.kill_server_mid_batch(kill_tick, devA, ssrcA, after_n=3)
        harness.run(ticks)

        assert any("mid-batch" in label and "DISARMED" not in label
                   for _, label in harness.log)
        for ref, got in zip(ref_runs, cl_runs):
            assert got.frames == ticks            # zero lost requests
            a, b = _responses(ref), _responses(got)
            assert len(a) == len(b) == ticks
            for x, y in zip(a, b):
                np.testing.assert_array_equal(x, y)
        fo = rt.stats()["failover"]
        assert fo["redispatches"] >= 1
        assert fo["parked_now"] == 0
        # the healthy ticks really exercised the mesh layout
        assert rt.stats()["query_batching"]["sharded_frames"] > 0
        assert runB.frames >= (ticks - kill_tick) * n_clients
