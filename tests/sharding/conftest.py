"""Sharding-test device forging: 8 host CPU devices for the whole process.

XLA fixes the device count at backend init.  pytest imports every conftest
during collection — before any test body touches a jax backend — so setting
``XLA_FLAGS`` here means the tier-1 run (which collects this directory)
exercises the mesh-sharded serve path on stock CI hardware: 8 forged CPU
devices, ``make_host_mesh()`` -> an 8-way data axis.

Tests that genuinely need more than one device carry the ``multidevice``
marker (pytest.ini) and are auto-skipped by the root conftest when the
backend initialized too early with fewer — e.g. a narrowed run of another
directory that happened to import this one late.  Everything else in the
suite is device-count-agnostic: donation/interpret-mode switches key off
``jax.default_backend()`` (still "cpu"), and plain jits place on device 0.
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8"
                               ).strip()
