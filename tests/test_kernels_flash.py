"""Pallas flash-attention kernel vs jnp oracle (shape sweeps, causal), plus
the SERVE-PATH trust anchors (DESIGN.md §7): flash prefill and cached-KV
decode-step attention vs the full-softmax references in kernels/ref.py —
the fp32 tolerance pin that must hold before the kernel sits under
model-serving traffic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attn import flash_attention, flash_decode_step
from repro.kernels.ref import attn_decode_ref, attn_ref


def oracle(q, k, v, causal=True):
    s = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * q.shape[-1] ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((q.shape[1], k.shape[1]), bool))
        s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s, -1)
    return jnp.einsum("bst,btd->bsd", w, v.astype(jnp.float32)).astype(q.dtype)


@given(st.sampled_from([64, 128, 192, 256]), st.sampled_from([16, 32, 64]),
       st.sampled_from([16, 64]), st.sampled_from([32, 64]),
       st.integers(0, 2 ** 30))
@settings(max_examples=10, deadline=None)
def test_flash_matches_oracle(s, dk, dv, bq, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (2, s, dk))
    k = jax.random.normal(ks[1], (2, s, dk))
    v = jax.random.normal(ks[2], (2, s, dv))
    o = flash_attention(q, k, v, causal=True, bq=bq, bk=64)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oracle(q, k, v)),
                               atol=2e-5, rtol=2e-5)


def test_non_causal():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 128, 32))
    k = jax.random.normal(ks[1], (1, 128, 32))
    v = jax.random.normal(ks[2], (1, 128, 32))
    o = flash_attention(q, k, v, causal=False, bq=64, bk=64)
    np.testing.assert_allclose(np.asarray(o),
                               np.asarray(oracle(q, k, v, causal=False)),
                               atol=2e-5, rtol=2e-5)


def test_first_token_attends_only_itself():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 16))
    k = jax.random.normal(ks[1], (1, 64, 16))
    v = jax.random.normal(ks[2], (1, 64, 16))
    o = flash_attention(q, k, v, causal=True, bq=64, bk=64)
    np.testing.assert_allclose(np.asarray(o[0, 0]), np.asarray(v[0, 0]),
                               atol=1e-5)


class TestServePathPrefill:
    """flash_attention vs the kernels/ref.py full-softmax oracle — the
    PREFILL half of the serve path, including the GQA head grouping the
    model presets use."""

    @given(st.sampled_from([64, 128, 256]), st.sampled_from([1, 2, 4]),
           st.integers(0, 2 ** 30))
    @settings(max_examples=10, deadline=None)
    def test_matches_attn_ref_gqa(self, s, groups, seed):
        bh, dk, dv = 4, 32, 32
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = jax.random.normal(ks[0], (bh, s, dk))
        k = jax.random.normal(ks[1], (bh // groups, s, dk))
        v = jax.random.normal(ks[2], (bh // groups, s, dv))
        o = flash_attention(q, k, v, causal=True, bq=64, bk=64,
                            kv_groups=groups)
        ref = attn_ref(q, k, v, causal=True, kv_groups=groups)
        np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)


class TestServePathDecodeStep:
    """flash_decode_step vs attn_decode_ref — the cached-KV DECODE half:
    one query row against a partially filled cache, swept over fill levels,
    block sizes and GQA groups."""

    @given(st.sampled_from([8, 31, 63, 64, 100, 127]),
           st.sampled_from([32, 128]), st.sampled_from([1, 2, 4]),
           st.integers(0, 2 ** 30))
    @settings(max_examples=12, deadline=None)
    def test_matches_decode_ref(self, pos, bk, groups, seed):
        bh, sk, dk, dv = 4, 128, 32, 48
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = jax.random.normal(ks[0], (bh, dk))
        k = jax.random.normal(ks[1], (bh // groups, sk, dk))
        v = jax.random.normal(ks[2], (bh // groups, sk, dv))
        o = flash_decode_step(q, k, v, jnp.int32(pos), bk=bk,
                              kv_groups=groups)
        ref = attn_decode_ref(q, k, v, pos, kv_groups=groups)
        assert o.shape == (bh, dv)
        np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)

    @given(st.integers(0, 62), st.integers(0, 2 ** 30))
    @settings(max_examples=10, deadline=None)
    def test_cache_beyond_pos_has_no_influence(self, pos, seed):
        """The mask property the ring cache depends on: garbage (or stale
        epoch data) in cache rows past ``pos`` must not move the output by
        one ulp."""
        bh, sk, dk = 2, 64, 16
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        q = jax.random.normal(ks[0], (bh, dk))
        k = jax.random.normal(ks[1], (bh, sk, dk))
        v = jax.random.normal(ks[2], (bh, sk, dk))
        o1 = flash_decode_step(q, k, v, jnp.int32(pos), bk=32)
        noise = 100.0 * jax.random.normal(ks[3], (bh, sk, dk))
        tail = (jnp.arange(sk) > pos)[None, :, None]
        k2 = jnp.where(tail, k + noise, k)
        v2 = jnp.where(tail, v + noise, v)
        o2 = flash_decode_step(q, k2, v2, jnp.int32(pos), bk=32)
        np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))

    def test_pos_zero_attends_only_first_row(self):
        bh, sk, dk = 2, 64, 16
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (bh, dk))
        k = jax.random.normal(ks[1], (bh, sk, dk))
        v = jax.random.normal(ks[2], (bh, sk, dk))
        o = flash_decode_step(q, k, v, jnp.int32(0), bk=32)
        np.testing.assert_allclose(np.asarray(o), np.asarray(v[:, 0]),
                                   atol=1e-5)

    def test_decode_step_agrees_with_prefill_last_row(self):
        """Cross-kernel consistency: decoding position ``pos`` against the
        cache equals the last row of a causal prefill over the same
        sequence — the handoff the serve path makes at admission."""
        bh, s, d = 4, 64, 32
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks[0], (bh, s, d))
        k = jax.random.normal(ks[1], (bh, s, d))
        v = jax.random.normal(ks[2], (bh, s, d))
        pre = flash_attention(q, k, v, causal=True, bq=64, bk=64)
        step = flash_decode_step(q[:, -1], k, v, jnp.int32(s - 1), bk=64)
        np.testing.assert_allclose(np.asarray(pre[:, -1]), np.asarray(step),
                                   atol=2e-5, rtol=2e-5)
