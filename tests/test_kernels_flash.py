"""Pallas flash-attention kernel vs jnp oracle (shape sweeps, causal)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attn import flash_attention


def oracle(q, k, v, causal=True):
    s = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * q.shape[-1] ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((q.shape[1], k.shape[1]), bool))
        s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s, -1)
    return jnp.einsum("bst,btd->bsd", w, v.astype(jnp.float32)).astype(q.dtype)


@given(st.sampled_from([64, 128, 192, 256]), st.sampled_from([16, 32, 64]),
       st.sampled_from([16, 64]), st.sampled_from([32, 64]),
       st.integers(0, 2 ** 30))
@settings(max_examples=10, deadline=None)
def test_flash_matches_oracle(s, dk, dv, bq, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (2, s, dk))
    k = jax.random.normal(ks[1], (2, s, dk))
    v = jax.random.normal(ks[2], (2, s, dv))
    o = flash_attention(q, k, v, causal=True, bq=bq, bk=64)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oracle(q, k, v)),
                               atol=2e-5, rtol=2e-5)


def test_non_causal():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 128, 32))
    k = jax.random.normal(ks[1], (1, 128, 32))
    v = jax.random.normal(ks[2], (1, 128, 32))
    o = flash_attention(q, k, v, causal=False, bq=64, bk=64)
    np.testing.assert_allclose(np.asarray(o),
                               np.asarray(oracle(q, k, v, causal=False)),
                               atol=2e-5, rtol=2e-5)


def test_first_token_attends_only_itself():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 16))
    k = jax.random.normal(ks[1], (1, 64, 16))
    v = jax.random.normal(ks[2], (1, 64, 16))
    o = flash_attention(q, k, v, causal=True, bq=64, bk=64)
    np.testing.assert_allclose(np.asarray(o[0, 0]), np.asarray(v[0, 0]),
                               atol=1e-5)
