"""200-tick mixed-workload soak (DESIGN.md §3) — `pytest -m soak`.

One deterministic long run combining pub/sub streaming, batched query
serving, and a scripted server death + revival, asserting the global
invariants the per-feature tests can't see:

* every client request is eventually answered — no frame is lost to the
  outage, parked frames all resume;
* pub/sub frame loss is exactly what the leaky-channel drop accounting in
  ``Runtime.stats`` declares — nothing vanishes unaccounted;
* the executable cache does not grow across death/rebind/revival — a
  revived topology reuses its fingerprint, it never retraces.

Excluded from tier-1 by the ``soak`` marker (pytest.ini); the chaos
schedule is tick-scripted, so the run is bit-reproducible.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import TensorSpec, parse_launch
from repro.core.elements import register_model
from repro.core.plan import executable_cache_info
from repro.runtime import Device, Runtime

TICKS = 200
KILL_AT, REVIVE_AT = 60, 90
N_PLAIN_CLIENTS = 3

pytestmark = pytest.mark.soak


@pytest.fixture(scope="module", autouse=True)
def models():
    def init(rng):
        return {"w": jax.random.normal(rng, (12, 4)) * 0.3}

    def apply(p, x):
        return x.astype(jnp.float32).reshape(1, -1) @ p["w"]

    register_model("soaksvc", init, apply,
                   out_specs=(TensorSpec((1, 4), "float32"),))

    def init2(rng):
        return {"w": jax.random.normal(rng, (12, 4)) * 0.1,
                "b": jnp.ones((4,))}

    def apply2(p, x):
        return x.astype(jnp.float32).reshape(1, -1) @ p["w"] + p["b"]

    register_model("soaksvc2", init2, apply2,
                   out_specs=(TensorSpec((1, 4), "float32"),))


def test_mixed_workload_soak(chaos):
    rt = Runtime(query_batch=4, lease_ticks=3)

    # consumer FIRST so its rx attaches before any frame is published —
    # every published frame is then either consumed, dropped (accounted),
    # or still queued: the conservation law asserted below
    viewer = Device("viewer")
    vp = parse_launch(
        "mqttsrc sub-topic=cam/live name=vsrc ! "
        "tensor_query_client operation=svc name=vqc ! appsink name=vres")
    viewer_run = viewer.add_pipeline(vp, jit=False)
    rt.add_device(viewer)

    cam = Device("cam")
    cp = parse_launch(
        "testsrc width=2 height=2 ! tensor_converter ! "
        "mqttsink pub-topic=cam/live name=csnk")
    cam_run = cam.add_pipeline(cp, jit=False)
    rt.add_device(cam)

    hub = Device("hub")
    sp = parse_launch(
        "tensor_query_serversrc operation=svc name=ssrc ! "
        "tensor_filter model=soaksvc ! tensor_query_serversink name=ssink")
    sp.elements["ssink"].pair_with(sp.elements["ssrc"])
    hub_run = hub.add_pipeline(sp, jit=False)
    rt.add_device(hub)

    client_runs = []
    for i in range(N_PLAIN_CLIENTS):
        dev = Device(f"tv{i}")
        pc = parse_launch(
            "testsrc width=2 height=2 ! tensor_converter ! "
            "tensor_query_client operation=svc name=qc ! appsink name=res")
        client_runs.append(dev.add_pipeline(pc, jit=False))
        rt.add_device(dev)

    harness = chaos(rt)
    harness.kill_server(KILL_AT, hub, sp.elements["ssrc"], crash=True)
    harness.revive_server(REVIVE_AT, hub, sp.elements["ssrc"])

    harness.run(50)
    cache_mid = executable_cache_info()
    harness.run(TICKS - 50)

    stats = rt.stats()

    # -- every client request eventually answered --------------------------------
    assert stats["failover"]["parked_now"] == 0
    outage = REVIVE_AT - KILL_AT
    for run in client_runs + [viewer_run]:
        assert run.frames + run.skipped == TICKS
        assert len(run.sink_log[next(iter(run.sink_log))]) == run.frames
        # the outage stalls (parks/skips) frames but loses none: everything
        # outside the outage window was answered on cadence
        assert run.frames >= TICKS - outage - 2
    assert hub_run.frames == sum(r.frames for r in client_runs + [viewer_run])
    assert stats["failover"]["parked_total"] > 0        # the outage did park

    # -- pub/sub conservation: published == consumed + dropped + queued ----------
    snk = cp.elements["csnk"].channel
    vsrc = vp.elements["vsrc"]
    published = snk.msgs_sent
    assert published == cam_run.frames
    still_queued = len(vsrc._rx) + len(vsrc._pushback)
    consumed = viewer_run.frames
    declared_drops = stats["viewer/p0"]["drops"]
    assert declared_drops == vsrc._rx.drops
    assert published == consumed + declared_drops + still_queued
    # the outage overflowed the viewer's bounded rx queue — drops are real
    assert declared_drops > 0

    # -- executable cache stays bounded across death/rebind/revival --------------
    cache_end = executable_cache_info()
    assert cache_end["fingerprints"] <= cache_mid["fingerprints"]
    assert cache_end["executables"] <= cache_mid["executables"]

    # -- per-client response channels stay bounded across the outage -------------
    # (regression: kill/revive used to leak one orphaned Channel per client
    # per epoch; the down/register events must release them, leaving at most
    # one live channel per bound client — viewer + the plain clients)
    ep = sp.elements["ssrc"].endpoint
    assert len(ep.responses) <= N_PLAIN_CLIENTS + 1


def test_reconfig_soak(chaos):
    """200-tick hot-swap churn under chaos (DESIGN.md §6): the serving
    model is hot-swapped every 40 ticks while pub/sub streams, batched
    query serving, and a scripted mid-warm server death all run — one swap
    is deliberately killed inside its warm window and must ROLL BACK (never
    limbo), the others commit, and the global conservation law still
    balances to the frame at the end."""
    from repro.core.element import element_factory

    rt = Runtime(query_batch=4, lease_ticks=3)

    viewer = Device("viewer")
    vp = parse_launch(
        "mqttsrc sub-topic=cam/live name=vsrc ! "
        "tensor_query_client operation=svc name=vqc ! appsink name=vres")
    viewer_run = viewer.add_pipeline(vp, jit=False)
    rt.add_device(viewer)

    cam = Device("cam")
    cp = parse_launch(
        "testsrc width=2 height=2 ! tensor_converter ! "
        "mqttsink pub-topic=cam/live name=csnk")
    cam_run = cam.add_pipeline(cp, jit=False)
    rt.add_device(cam)

    hub = Device("hub")
    sp = parse_launch(
        "tensor_query_serversrc operation=svc name=ssrc ! "
        "tensor_filter model=soaksvc name=filt ! "
        "tensor_query_serversink name=ssink")
    sp.elements["ssink"].pair_with(sp.elements["ssrc"])
    hub_run = hub.add_pipeline(sp, jit=False)
    rt.add_device(hub)

    client_runs = []
    for i in range(N_PLAIN_CLIENTS):
        dev = Device(f"tv{i}")
        pc = parse_launch(
            "testsrc width=2 height=2 ! tensor_converter ! "
            "tensor_query_client operation=svc name=qc ! appsink name=res")
        client_runs.append(dev.add_pipeline(pc, jit=False))
        rt.add_device(dev)

    harness = chaos(rt)
    rcs = []

    def swap_to(model):
        def fire():
            rcs.append(rt.reconfigure(
                hub_run, hub_run.pipe.reconfig().swap(
                    "filt", element_factory("tensor_filter", model=model)),
                warm_ticks=2))
        return fire

    harness.at(40, swap_to("soaksvc2"), "hot swap filt -> soaksvc2")
    harness.at(80, swap_to("soaksvc"), "hot swap filt -> soaksvc")
    # this swap's warm window is cut short by the kill: it must roll back
    harness.at(120, swap_to("soaksvc2"),
               "hot swap filt -> soaksvc2 (dies mid-warm)")
    harness.kill_server(121, hub, sp.elements["ssrc"], crash=True)
    harness.revive_server(130, hub, sp.elements["ssrc"])
    harness.at(160, swap_to("soaksvc2"), "hot swap filt -> soaksvc2")

    harness.run(100)
    cache_mid = executable_cache_info()
    harness.run(TICKS - 100)

    stats = rt.stats()

    # -- every swap terminated: 3 committed, the mid-warm one rolled back --------
    assert [rc.status for rc in rcs] == \
        ["committed", "committed", "rolled_back", "committed"]
    assert rcs[2].reason == "target-dead"
    rst = stats["reconfig"]
    assert rst["planned"] == 3
    assert rst["rollbacks"] == 1
    assert rst["unplanned"] >= 2            # the kill and the revival
    assert rst["pending"] == 0              # nothing in limbo at the end
    # the last committed swap's model is live on the hub
    assert "b" in hub_run.params["filt"]

    # -- zero frame loss through swaps, death, revival ---------------------------
    assert stats["failover"]["parked_now"] == 0
    for run in client_runs + [viewer_run]:
        assert run.frames + run.skipped == TICKS
        assert len(run.sink_log[next(iter(run.sink_log))]) == run.frames
    assert hub_run.frames == sum(r.frames for r in client_runs + [viewer_run])
    assert stats["failover"]["parked_total"] > 0     # the outage did park

    # -- pub/sub conservation survives the churn ---------------------------------
    snk = cp.elements["csnk"].channel
    vsrc = vp.elements["vsrc"]
    published = snk.msgs_sent
    assert published == cam_run.frames
    still_queued = len(vsrc._rx) + len(vsrc._pushback)
    consumed = viewer_run.frames
    declared_drops = stats["viewer/p0"]["drops"]
    assert published == consumed + declared_drops + still_queued

    # -- the exec registry saw every topology by mid-run: no growth after --------
    cache_end = executable_cache_info()
    assert cache_end["fingerprints"] <= cache_mid["fingerprints"]
    assert cache_end["executables"] <= cache_mid["executables"]
