"""Pub/Sub streams (paper §4.2.1): transports, codecs, byte accounting,
leaky-queue drops."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Broker, Channel, StreamBuffer, Transport, parse_launch
from repro.core import compression as comp
from repro.runtime import Device, Runtime


class TestChannel:
    def test_leaky_drop_oldest(self):
        ch = Channel(capacity=2)
        for i in range(4):
            ch.push(StreamBuffer(tensors=(jnp.full((1,), i),)))
        assert ch.drops == 2
        assert float(ch.pop().tensor[0]) == 2.0  # oldest surviving

    def test_byte_accounting(self):
        ch = Channel()
        buf = StreamBuffer(tensors=(jnp.zeros((10, 10), jnp.float32),))
        ch.push(buf)
        assert ch.bytes_sent == 400


class TestCodecs:
    def test_quant8_roundtrip_buffer(self):
        x = jnp.linspace(-3, 3, 96).reshape(8, 12)
        buf = StreamBuffer(tensors=(x,))
        enc, nbytes = comp.encode(buf, "quant8")
        assert nbytes < buf.nbytes()  # 4x smaller + scales
        dec = comp.decode(enc, "quant8")
        assert dec.tensors[0].shape == (8, 12)
        np.testing.assert_allclose(np.asarray(dec.tensors[0]), np.asarray(x),
                                   atol=float(jnp.max(jnp.abs(x))) / 127 + 1e-6)

    def test_sparse_roundtrip_buffer(self):
        x = jnp.zeros((400,)).at[jnp.arange(0, 400, 13)].set(1.5)
        buf = StreamBuffer(tensors=(x,))
        enc, nbytes = comp.encode(buf, "sparse")
        dec = comp.decode(enc, "sparse")
        np.testing.assert_allclose(np.asarray(dec.tensors[0]), np.asarray(x),
                                   atol=1e-6)

    def test_unknown_codec(self):
        with pytest.raises(ValueError):
            comp.encode(StreamBuffer(tensors=(jnp.zeros(1),)), "zstd")


class TestTransports:
    def _pub_sub(self, transport: str, codec: str = "none", ticks: int = 4):
        rt = Runtime()
        pub = Device("pub")
        # typecast to float32: the paper's compression targets activation /
        # feature streams (uint8 video is already dense 1B/elem)
        p = parse_launch(
            f"testsrc width=16 height=16 ! tensor_converter ! "
            f"tensor_transform mode=arithmetic option=typecast:float32 ! "
            f"mqttsink pub-topic=t transport={transport} codec={codec} name=snk")
        pub.add_pipeline(p, jit=False)
        rt.add_device(pub)
        sub = Device("sub")
        s = parse_launch(
            f"mqttsrc sub-topic=t transport={transport} codec={codec} ! "
            f"appsink name=o")
        sub.add_pipeline(s, jit=False)
        rt.add_device(sub)
        rt.run(ticks)
        return rt, pub, sub, p.elements["snk"]

    def test_relay_counts_broker_bytes(self):
        rt, pub, sub, snk = self._pub_sub("relay")
        assert rt.broker.relay_msgs == 4
        assert rt.broker.relay_bytes == snk.channel.bytes_sent

    def test_hybrid_bypasses_broker_data_plane(self):
        """The MQTT-hybrid design point: discovery via broker, zero broker
        data bytes (Fig. 7's overhead elimination)."""
        rt, pub, sub, snk = self._pub_sub("hybrid")
        assert rt.broker.relay_bytes == 0
        assert snk.channel.bytes_sent > 0
        assert sub.runs[0].frames >= 3

    def test_quant8_codec_cuts_wire_bytes(self):
        _, _, sub1, snk_raw = self._pub_sub("hybrid", codec="none")
        _, _, sub2, snk_q = self._pub_sub("hybrid", codec="quant8")
        # f32 frames: ~4x narrower on the wire
        assert snk_q.channel.bytes_sent < 0.3 * snk_raw.channel.bytes_sent
        # frames still arrive intact
        assert sub2.runs[0].last_outputs["o"].tensor.shape == (16, 16, 3)

    def test_wildcard_subscription(self):
        rt = Runtime()
        pub = Device("pub")
        p = parse_launch("testsrc width=4 height=4 ! tensor_converter ! "
                         "mqttsink pub-topic=cam/left/rgb")
        pub.add_pipeline(p, jit=False)
        rt.add_device(pub)
        sub = Device("sub")
        s = parse_launch("mqttsrc sub-topic=cam/# ! appsink name=o")
        sub.add_pipeline(s, jit=False)
        rt.add_device(sub)
        rt.run(2)
        assert sub.runs[0].frames >= 1

    def test_pubsub_failover(self):
        rt = Runtime()
        for name in ("pubA", "pubB"):
            d = Device(name)
            p = parse_launch(f"testsrc width=4 height=4 ! tensor_converter ! "
                             f"mqttsink pub-topic=svc/{name} name=sink_{name}")
            d.add_pipeline(p, jit=False)
            rt.add_device(d)
        sub = Device("sub")
        s = parse_launch("mqttsrc sub-topic=svc/# name=src ! appsink name=o")
        sub.add_pipeline(s, jit=False)
        rt.add_device(sub)
        rt.run(2)
        src = s.elements["src"]
        first = src.binding.current
        rt.broker.mark_down(first)
        rt.run(2)
        assert src.binding.current is not first
        assert sub.runs[0].frames >= 3
